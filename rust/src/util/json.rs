//! Minimal JSON parser/writer (serde_json is not available offline).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) plus `\uXXXX` escapes with surrogate pairs.
//! Object key order is preserved (insertion order), which keeps manifests
//! and metrics diffs stable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::new(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: object from key/value pairs.
    pub fn obj(kvs: Vec<(&str, Value)>) -> Value {
        Value::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Value {
        Value::Arr(v.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Value {
        Value::Arr(v.iter().map(|&x| Value::Num(x)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 1-space indentation (matches python `json.dumps(indent=1)`).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_str(out, s),
            Value::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kvs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if n == n.trunc() && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset context.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl JsonError {
    fn new(msg: String) -> Self {
        JsonError { msg, offset: 0 }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: require \uXXXX low surrogate
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let full = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00));
                                    char::from_u32(full)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced past the digits
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
            self.i += 1;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(kvs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Parse into a sorted map (handy for comparisons in tests).
pub fn parse_to_map(input: &str) -> Result<BTreeMap<String, Value>, JsonError> {
    match parse(input)? {
        Value::Obj(kvs) => Ok(kvs.into_iter().collect()),
        _ => Err(JsonError::new("top-level value is not an object".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_i64(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        // surrogate pair: U+1F600
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model":"edgenet","blocks":[{"index":0,"flops":12345}],"f":0.25,"neg":-3}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn big_ints_exact() {
        let v = parse("123456789012345").unwrap();
        assert_eq!(v.as_i64(), Some(123456789012345));
        assert_eq!(v.to_string(), "123456789012345");
    }
}
