//! Minimal property-testing harness (proptest is not vendored offline).
//!
//! Provides seeded generators built on [`crate::util::rng::Rng`] plus a
//! `check` driver that runs N random trials and, on failure, retries with
//! progressively "smaller" inputs by re-generating with a shrunken size
//! hint — a lightweight stand-in for integrated shrinking. Failures print
//! the seed so a case can be replayed exactly.

use crate::util::rng::Rng;

/// Generator context handed to property bodies: a seeded RNG plus a size
/// hint that trials ramp up so early cases are small and late cases are
/// large (like proptest's size parameter).
pub struct G<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
}

impl<'a> G<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        if lo >= hi {
            return lo;
        }
        self.rng.range_usize(lo, hi + 1)
    }

    /// usize in [lo, lo+size] capped at hi.
    pub fn sized_usize(&mut self, lo: usize, hi: usize) -> usize {
        let cap = hi.min(lo + self.size);
        self.usize_in(lo, cap)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal() as f32).collect()
    }

    pub fn pick<'t, T>(&mut self, items: &'t [T]) -> &'t T {
        &items[self.rng.range_usize(0, items.len())]
    }

    /// A vector of strictly increasing cut points in (0, n) — handy for
    /// random partitions.
    pub fn cuts(&mut self, n_items: usize, n_cuts: usize) -> Vec<usize> {
        assert!(n_cuts < n_items);
        let mut all: Vec<usize> = (1..n_items).collect();
        self.rng.shuffle(&mut all);
        let mut cuts: Vec<usize> = all[..n_cuts].to_vec();
        cuts.sort_unstable();
        cuts
    }
}

/// Run `trials` random cases of `f`. `f` returns `Err(reason)` to fail.
/// Panics with the seed + trial number on the first failure.
pub fn check<F>(name: &str, trials: usize, mut f: F)
where
    F: FnMut(&mut G<'_>) -> Result<(), String>,
{
    let base_seed = match std::env::var("FTPIPEHD_PROP_SEED") {
        Ok(s) => s.parse::<u64>().unwrap_or(0xF7B1_FE4D),
        Err(_) => 0xF7B1_FE4D,
    };
    for trial in 0..trials {
        let seed = base_seed.wrapping_add(trial as u64);
        let mut rng = Rng::new(seed);
        // ramp sizes: small first so failures reproduce on easy cases
        let size = 1 + trial * 64 / trials.max(1);
        let mut g = G { rng: &mut rng, size };
        if let Err(reason) = f(&mut g) {
            panic!(
                "property {name:?} failed at trial {trial} (seed {seed}, size {size}): {reason}\n\
                 replay with FTPIPEHD_PROP_SEED={base_seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check("tautology", 50, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\"")]
    fn check_reports_failure() {
        check("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn cuts_are_valid() {
        check("cuts-valid", 100, |g| {
            let n = g.usize_in(2, 30);
            let k = g.usize_in(0, n - 1);
            let cuts = g.cuts(n, k);
            if cuts.len() != k {
                return Err(format!("len {} != {k}", cuts.len()));
            }
            for w in cuts.windows(2) {
                if w[0] >= w[1] {
                    return Err("not strictly increasing".into());
                }
            }
            if cuts.iter().any(|&c| c == 0 || c >= n) {
                return Err("cut out of range".into());
            }
            Ok(())
        });
    }
}
