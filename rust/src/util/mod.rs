//! Offline substrates (no external crates available — see DESIGN.md §1).

pub mod benchkit;
pub mod json;
pub mod logging;
pub mod npy;
pub mod prop;
pub mod rng;
