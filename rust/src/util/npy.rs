//! Minimal NumPy `.npy` v1.0 reader/writer for f32 tensors.
//!
//! Used for cross-language weight interchange: the Rust side exports
//! trained parameters that `python/tests/test_interchange.py` loads with
//! `np.load` and vice versa (the AOT init weights could equally ship as
//! npy; they predate this module and stay raw-f32).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Write a little-endian f32 tensor as `.npy` v1.0.
pub fn write_f32(path: impl AsRef<Path>, shape: &[usize], data: &[f32]) -> Result<()> {
    let expect: usize = shape.iter().product();
    if expect != data.len() {
        bail!("shape {:?} wants {} elements, got {}", shape, expect, data.len());
    }
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&[1, 0])?; // version 1.0
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for &x in data {
        f.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Read a little-endian f32 `.npy` (v1.x) tensor. Returns (shape, data).
pub fn read_f32(path: impl AsRef<Path>) -> Result<(Vec<usize>, Vec<f32>)> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an npy file");
    }
    let mut ver = [0u8; 2];
    f.read_exact(&mut ver)?;
    let header_len = match ver[0] {
        1 => {
            let mut l = [0u8; 2];
            f.read_exact(&mut l)?;
            u16::from_le_bytes(l) as usize
        }
        2 | 3 => {
            let mut l = [0u8; 4];
            f.read_exact(&mut l)?;
            u32::from_le_bytes(l) as usize
        }
        v => bail!("unsupported npy version {v}"),
    };
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8_lossy(&header);
    if !header.contains("'<f4'") {
        bail!("expected '<f4' dtype, header: {header}");
    }
    if header.contains("'fortran_order': True") {
        bail!("fortran order not supported");
    }
    let shape = parse_shape(&header)?;
    let n: usize = shape.iter().product();
    let mut bytes = vec![0u8; n * 4];
    f.read_exact(&mut bytes)?;
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((shape, data))
}

fn parse_shape(header: &str) -> Result<Vec<usize>> {
    let start = header.find("'shape':").context("no shape key")? + 8;
    let open = header[start..].find('(').context("no (")? + start;
    let close = header[open..].find(')').context("no )")? + open;
    let inner = &header[open + 1..close];
    let mut shape = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        shape.push(part.parse::<usize>().with_context(|| format!("bad dim {part:?}"))?);
    }
    Ok(shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ftpipehd-npy-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_2d() {
        let p = tmp("a.npy");
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        write_f32(&p, &[3, 4], &data).unwrap();
        let (shape, back) = read_f32(&p).unwrap();
        assert_eq!(shape, vec![3, 4]);
        assert_eq!(back, data);
    }

    #[test]
    fn roundtrip_1d_and_scalar() {
        let p = tmp("b.npy");
        write_f32(&p, &[5], &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let (shape, back) = read_f32(&p).unwrap();
        assert_eq!(shape, vec![5]);
        assert_eq!(back.len(), 5);

        let p2 = tmp("c.npy");
        write_f32(&p2, &[], &[42.0]).unwrap();
        let (shape, back) = read_f32(&p2).unwrap();
        assert!(shape.is_empty());
        assert_eq!(back, vec![42.0]);
    }

    #[test]
    fn rejects_shape_mismatch_and_garbage() {
        let p = tmp("d.npy");
        assert!(write_f32(&p, &[2, 2], &[1.0]).is_err());
        std::fs::write(&p, b"not npy at all").unwrap();
        assert!(read_f32(&p).is_err());
    }

    #[test]
    fn header_is_64_byte_aligned() {
        let p = tmp("e.npy");
        write_f32(&p, &[7, 3], &vec![0.0; 21]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // data must start at a multiple of 64
        assert_eq!((bytes.len() - 21 * 4) % 64, 0);
    }
}
