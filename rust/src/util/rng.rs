//! Deterministic PRNG (the `rand` crate is not available offline).
//!
//! SplitMix64 for seeding + xoshiro256** for the stream — both standard,
//! well-tested generators with public test vectors. Everything that needs
//! randomness (synthetic data, capacity noise, property tests) takes an
//! explicit [`Rng`] so runs are reproducible from a single seed.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s =
            [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per device / per block).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            v.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_support() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
