//! Bench harness (criterion is not vendored): warmup + repeated timing
//! with mean / p50 / p95 / stddev, plus table/series printers used by the
//! per-figure benches to emit the same rows the paper reports.

use std::time::{Duration, Instant};

/// Summary statistics of a timed run.
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
}

impl Stats {
    pub fn from_secs(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let q = |p: f64| xs[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            n,
            mean,
            p50: q(0.5),
            p95: q(0.95),
            min: xs[0],
            max: xs[n - 1],
            stddev: var.sqrt(),
        }
    }

    pub fn fmt_ms(&self) -> String {
        format!(
            "mean={:.3}ms p50={:.3}ms p95={:.3}ms sd={:.3}ms (n={})",
            self.mean * 1e3,
            self.p50 * 1e3,
            self.p95 * 1e3,
            self.stddev * 1e3,
            self.n
        )
    }
}

/// Time `f` `iters` times after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_secs(samples)
}

/// Time a single execution.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            println!("{s}");
        };
        line(&self.headers);
        println!(
            "|{}|",
            w.iter().map(|x| "-".repeat(x + 2)).collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            line(r);
        }
    }
}

/// Serialize a table as JSON (for the CI bench artifact).
impl Table {
    pub fn to_json(&self, bench: &str) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::obj(vec![
            ("bench", Value::Str(bench.to_string())),
            ("skipped", Value::Bool(false)),
            (
                "headers",
                Value::Arr(self.headers.iter().map(|h| Value::Str(h.clone())).collect()),
            ),
            (
                "rows",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Value::Arr(r.iter().map(|c| Value::Str(c.clone())).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// When `FTPIPEHD_BENCH_JSON` names a file, write the bench results
/// there (CI uploads it as the BENCH_* trajectory artifact). `table` =
/// None records a skipped bench (e.g. artifacts absent) so the artifact
/// always exists.
pub fn emit_json(bench: &str, table: Option<&Table>) {
    use crate::util::json::Value;
    let Ok(path) = std::env::var("FTPIPEHD_BENCH_JSON") else {
        return;
    };
    let v = match table {
        Some(t) => t.to_json(bench),
        None => Value::obj(vec![
            ("bench", Value::Str(bench.to_string())),
            ("skipped", Value::Bool(true)),
        ]),
    };
    if let Err(e) = std::fs::write(&path, v.to_pretty()) {
        eprintln!("bench json: cannot write {path}: {e}");
    }
}

/// Print an (x, series...) block for figure-style data (easy to plot).
pub fn print_series(title: &str, xlabel: &str, names: &[&str], xs: &[f64], ys: &[Vec<f64>]) {
    println!("# {title}");
    println!("# {xlabel}\t{}", names.join("\t"));
    for (i, x) in xs.iter().enumerate() {
        let row: Vec<String> = ys.iter().map(|s| format!("{:.6}", s[i])).collect();
        println!("{x:.4}\t{}", row.join("\t"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_secs(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench(2, 10, || count += 1);
        assert_eq!(count, 12);
        assert_eq!(s.n, 10);
    }
}
