//! Bench harness (criterion is not vendored): warmup + repeated timing
//! with mean / p50 / p95 / stddev, plus table/series printers used by the
//! per-figure benches to emit the same rows the paper reports.

use std::time::{Duration, Instant};

/// Summary statistics of a timed run.
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
}

impl Stats {
    pub fn from_secs(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let q = |p: f64| xs[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            n,
            mean,
            p50: q(0.5),
            p95: q(0.95),
            min: xs[0],
            max: xs[n - 1],
            stddev: var.sqrt(),
        }
    }

    pub fn fmt_ms(&self) -> String {
        format!(
            "mean={:.3}ms p50={:.3}ms p95={:.3}ms sd={:.3}ms (n={})",
            self.mean * 1e3,
            self.p50 * 1e3,
            self.p95 * 1e3,
            self.stddev * 1e3,
            self.n
        )
    }
}

/// Time `f` `iters` times after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_secs(samples)
}

/// Time a single execution.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            println!("{s}");
        };
        line(&self.headers);
        println!(
            "|{}|",
            w.iter().map(|x| "-".repeat(x + 2)).collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            line(r);
        }
    }
}

/// Serialize a table as JSON (for the CI bench artifact).
impl Table {
    pub fn to_json(&self, bench: &str) -> crate::util::json::Value {
        self.to_json_with_metrics(bench, &[])
    }

    /// Like [`Table::to_json`], with a flat `metrics` list of named
    /// machine-comparable numbers (byte ratios, relative timings) — the
    /// values the CI bench-regression gate diffs against
    /// `BENCH_BASELINE.json` (absolute wall times vary too much across
    /// runners to gate on; ratios measured within one process do not).
    pub fn to_json_with_metrics(
        &self,
        bench: &str,
        metrics: &[(String, f64)],
    ) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::obj(vec![
            ("bench", Value::Str(bench.to_string())),
            ("skipped", Value::Bool(false)),
            (
                "headers",
                Value::Arr(self.headers.iter().map(|h| Value::Str(h.clone())).collect()),
            ),
            (
                "rows",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Value::Arr(r.iter().map(|c| Value::Str(c.clone())).collect())
                        })
                        .collect(),
                ),
            ),
            (
                "metrics",
                Value::Arr(
                    metrics
                        .iter()
                        .map(|(name, value)| {
                            Value::obj(vec![
                                ("name", Value::Str(name.clone())),
                                ("value", Value::Num(*value)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One baseline-vs-current comparison produced by [`compare_metrics`].
#[derive(Debug, Clone)]
pub struct MetricDelta {
    pub name: String,
    pub baseline: f64,
    pub current: f64,
    /// true when lower values are better for this metric.
    pub lower_is_better: bool,
    /// current/baseline (so 1.0 = unchanged).
    pub ratio: f64,
    /// Regressed past the tolerance in the metric's bad direction.
    pub regressed: bool,
}

impl MetricDelta {
    pub fn summary(&self) -> String {
        format!(
            "{:<34} baseline {:>9.4}  current {:>9.4}  ({:+.1}%){}",
            self.name,
            self.baseline,
            self.current,
            (self.ratio - 1.0) * 100.0,
            if self.regressed { "  REGRESSION" } else { "" }
        )
    }
}

/// Diff a bench JSON (as emitted by [`emit_json`] /
/// [`Table::to_json_with_metrics`]) against a committed baseline.
///
/// Baseline shape:
/// ```json
/// { "bench": "micro_runtime", "tolerance": 0.25,
///   "metrics": [ {"name": "...", "value": 3.99, "better": "higher"} ] }
/// ```
///
/// Every baseline metric must exist in the current run (a silently
/// dropped metric would otherwise un-gate itself); a metric regresses
/// when it moves past the tolerance in its bad direction. The tolerance
/// is `tolerance_override` when given (an explicit operator choice),
/// else the baseline's `tolerance` field, else 25%.
pub fn compare_metrics(
    baseline: &crate::util::json::Value,
    current: &crate::util::json::Value,
    tolerance_override: Option<f64>,
) -> anyhow::Result<Vec<MetricDelta>> {
    use anyhow::{anyhow, ensure};
    ensure!(
        current.get("skipped").and_then(|v| v.as_bool()) != Some(true),
        "current bench run is marked skipped — no metrics to gate on"
    );
    let tolerance = tolerance_override
        .or_else(|| baseline.get("tolerance").and_then(|v| v.as_f64()))
        .unwrap_or(0.25);
    let cur: std::collections::BTreeMap<String, f64> = current
        .get("metrics")
        .and_then(|v| v.as_arr())
        .unwrap_or(&[])
        .iter()
        .filter_map(|m| {
            Some((
                m.get("name")?.as_str()?.to_string(),
                m.get("value")?.as_f64()?,
            ))
        })
        .collect();
    let specs = baseline
        .get("metrics")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("baseline has no metrics array"))?;
    let mut out = Vec::with_capacity(specs.len());
    for spec in specs {
        let name = spec
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("baseline metric without a name"))?;
        let value = spec
            .get("value")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("baseline metric {name:?} without a value"))?;
        ensure!(value > 0.0, "baseline metric {name:?} must be positive");
        let lower_is_better = match spec.get("better").and_then(|v| v.as_str()) {
            Some("lower") => true,
            Some("higher") | None => false,
            Some(other) => return Err(anyhow!("metric {name:?}: bad direction {other:?}")),
        };
        let current_v = *cur
            .get(name)
            .ok_or_else(|| anyhow!("current bench output is missing metric {name:?}"))?;
        let ratio = current_v / value;
        let regressed = if lower_is_better {
            ratio > 1.0 + tolerance
        } else {
            ratio < 1.0 / (1.0 + tolerance)
        };
        out.push(MetricDelta {
            name: name.to_string(),
            baseline: value,
            current: current_v,
            lower_is_better,
            ratio,
            regressed,
        });
    }
    Ok(out)
}

/// When `FTPIPEHD_BENCH_JSON` names a file, write the bench results
/// there (CI uploads it as the BENCH_* trajectory artifact). `table` =
/// None records a skipped bench (e.g. artifacts absent) so the artifact
/// always exists.
pub fn emit_json(bench: &str, table: Option<&Table>) {
    emit_json_with_metrics(bench, table, &[]);
}

/// [`emit_json`] with gate metrics attached (see
/// [`Table::to_json_with_metrics`] and [`compare_metrics`]).
pub fn emit_json_with_metrics(bench: &str, table: Option<&Table>, metrics: &[(String, f64)]) {
    use crate::util::json::Value;
    let Ok(path) = std::env::var("FTPIPEHD_BENCH_JSON") else {
        return;
    };
    let v = match table {
        Some(t) => t.to_json_with_metrics(bench, metrics),
        None => Value::obj(vec![
            ("bench", Value::Str(bench.to_string())),
            ("skipped", Value::Bool(true)),
        ]),
    };
    if let Err(e) = std::fs::write(&path, v.to_pretty()) {
        eprintln!("bench json: cannot write {path}: {e}");
    }
}

/// Print an (x, series...) block for figure-style data (easy to plot).
pub fn print_series(title: &str, xlabel: &str, names: &[&str], xs: &[f64], ys: &[Vec<f64>]) {
    println!("# {title}");
    println!("# {xlabel}\t{}", names.join("\t"));
    for (i, x) in xs.iter().enumerate() {
        let row: Vec<String> = ys.iter().map(|s| format!("{:.6}", s[i])).collect();
        println!("{x:.4}\t{}", row.join("\t"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_secs(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench(2, 10, || count += 1);
        assert_eq!(count, 12);
        assert_eq!(s.n, 10);
    }

    fn gate_fixture(current_ratio: f64, current_rel: f64) -> crate::util::json::Value {
        let mut t = Table::new(&["case", "mean"]);
        t.row(&["x".into(), "1 ms".into()]);
        t.to_json_with_metrics(
            "micro_runtime",
            &[
                ("bytes_ratio".to_string(), current_ratio),
                ("rel_time".to_string(), current_rel),
            ],
        )
    }

    fn gate_baseline() -> crate::util::json::Value {
        crate::util::json::parse(
            r#"{ "bench": "micro_runtime", "tolerance": 0.25, "metrics": [
                 {"name": "bytes_ratio", "value": 4.0, "better": "higher"},
                 {"name": "rel_time", "value": 1.0, "better": "lower"} ] }"#,
        )
        .unwrap()
    }

    #[test]
    fn compare_metrics_passes_within_tolerance() {
        let deltas = compare_metrics(&gate_baseline(), &gate_fixture(3.5, 1.2), None).unwrap();
        assert_eq!(deltas.len(), 2);
        assert!(deltas.iter().all(|d| !d.regressed), "{deltas:?}");
    }

    #[test]
    fn compare_metrics_flags_regressions_in_the_bad_direction() {
        // higher-is-better ratio collapses by >25%
        let deltas = compare_metrics(&gate_baseline(), &gate_fixture(3.0, 1.0), None).unwrap();
        assert!(deltas[0].regressed && !deltas[1].regressed);
        // lower-is-better relative time blows past +25%
        let deltas = compare_metrics(&gate_baseline(), &gate_fixture(4.0, 1.3), None).unwrap();
        assert!(!deltas[0].regressed && deltas[1].regressed);
        // improvements in the good direction never flag
        let deltas = compare_metrics(&gate_baseline(), &gate_fixture(8.0, 0.1), None).unwrap();
        assert!(deltas.iter().all(|d| !d.regressed));
    }

    #[test]
    fn compare_metrics_cli_override_beats_the_baseline_tolerance() {
        // the baseline pins 25%; an explicit 60% override must loosen it
        let loose = compare_metrics(&gate_baseline(), &gate_fixture(3.0, 1.5), Some(0.6)).unwrap();
        assert!(loose.iter().all(|d| !d.regressed), "{loose:?}");
        let strict = compare_metrics(&gate_baseline(), &gate_fixture(3.0, 1.5), None).unwrap();
        assert!(strict.iter().all(|d| d.regressed));
    }

    #[test]
    fn compare_metrics_rejects_missing_metrics_and_skipped_runs() {
        let current = Table::new(&["case"]).to_json_with_metrics("micro_runtime", &[]);
        assert!(compare_metrics(&gate_baseline(), &current, None).is_err());
        let skipped = crate::util::json::parse(
            r#"{"bench": "micro_runtime", "skipped": true}"#,
        )
        .unwrap();
        assert!(compare_metrics(&gate_baseline(), &skipped, None).is_err());
    }
}
