//! Tiny leveled logger (the `log`/`env_logger` facade is enough for a
//! binary this size, but only `log` is vendored and without an emitter it
//! does nothing — so we keep one ~100-line implementation with run-time
//! level control via `FTPIPEHD_LOG`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Initialize from the `FTPIPEHD_LOG` env var (error|warn|info|debug|trace).
pub fn init_from_env() {
    let lvl = match std::env::var("FTPIPEHD_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_level(lvl);
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
    let _ = START.set(Instant::now());
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:>9.3}s {tag} {target}] {msg}", t.as_secs_f64());
}

#[macro_export]
macro_rules! log_error {
    ($($a:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($a)*),
        )
    };
}
#[macro_export]
macro_rules! log_warn {
    ($($a:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($a)*),
        )
    };
}
#[macro_export]
macro_rules! log_info {
    ($($a:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($a)*),
        )
    };
}
#[macro_export]
macro_rules! log_debug {
    ($($a:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($a)*),
        )
    };
}
#[macro_export]
macro_rules! log_trace {
    ($($a:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Trace,
            module_path!(),
            format_args!($($a)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
