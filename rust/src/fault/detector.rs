//! Gradient-timeout fault detector (paper §III-F).
//!
//! "After sending the intermediate result to the next worker in forwarding
//! a batch, a timer is set by only the central node. If the central node
//! does not receive the backward gradients of that batch when the timer
//! stops, the fault tolerance handler is triggered."

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Timer table: batch id -> deadline.
#[derive(Debug, Default)]
pub struct FaultDetector {
    deadlines: BTreeMap<u64, Instant>,
    timeout: Duration,
}

impl FaultDetector {
    pub fn new(timeout: Duration) -> FaultDetector {
        FaultDetector { deadlines: BTreeMap::new(), timeout }
    }

    /// Arm the timer for a batch whose activations were just sent out.
    pub fn arm(&mut self, batch: u64) {
        self.deadlines.insert(batch, Instant::now() + self.timeout);
    }

    /// Gradient for `batch` arrived — disarm.
    pub fn disarm(&mut self, batch: u64) {
        self.deadlines.remove(&batch);
    }

    /// The earliest overdue batch, if any.
    pub fn overdue(&self) -> Option<u64> {
        let now = Instant::now();
        self.deadlines
            .iter()
            .find(|(_, &dl)| now >= dl)
            .map(|(&b, _)| b)
    }

    /// Clear everything (fault handling resets all in-flight state).
    pub fn clear(&mut self) {
        self.deadlines.clear();
    }

    pub fn armed(&self) -> usize {
        self.deadlines.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arms_and_disarms() {
        let mut d = FaultDetector::new(Duration::from_secs(60));
        d.arm(3);
        d.arm(4);
        assert_eq!(d.armed(), 2);
        assert_eq!(d.overdue(), None);
        d.disarm(3);
        assert_eq!(d.armed(), 1);
    }

    #[test]
    fn detects_overdue_earliest_first() {
        let mut d = FaultDetector::new(Duration::from_millis(5));
        d.arm(7);
        d.arm(5);
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(d.overdue(), Some(5));
        d.clear();
        assert_eq!(d.overdue(), None);
    }
}
