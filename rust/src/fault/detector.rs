//! Gradient-timeout fault detector (paper §III-F).
//!
//! "After sending the intermediate result to the next worker in forwarding
//! a batch, a timer is set by only the central node. If the central node
//! does not receive the backward gradients of that batch when the timer
//! stops, the fault tolerance handler is triggered."
//!
//! All timing goes through the [`Clock`] seam, so the timer table is
//! byte-for-byte deterministic under a [`crate::sim::VirtualClock`] — the
//! scenario suite scripts "the timeout fires exactly here" instead of
//! sleeping and hoping.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::sim::clock::{real_clock, SharedClock};

/// Timer table: batch id -> deadline (in clock time).
#[derive(Debug)]
pub struct FaultDetector {
    deadlines: BTreeMap<u64, Duration>,
    timeout: Duration,
    clock: SharedClock,
}

impl FaultDetector {
    /// Wall-clock detector (production default).
    pub fn new(timeout: Duration) -> FaultDetector {
        FaultDetector::with_clock(timeout, real_clock())
    }

    /// Detector on an explicit clock (virtual in the scenario runner).
    pub fn with_clock(timeout: Duration, clock: SharedClock) -> FaultDetector {
        FaultDetector { deadlines: BTreeMap::new(), timeout, clock }
    }

    /// Arm the timer for a batch whose activations were just sent out.
    pub fn arm(&mut self, batch: u64) {
        self.deadlines.insert(batch, self.clock.now() + self.timeout);
    }

    /// Gradient for `batch` arrived — disarm.
    pub fn disarm(&mut self, batch: u64) {
        self.deadlines.remove(&batch);
    }

    /// The lowest-numbered overdue batch, if any.
    pub fn overdue(&self) -> Option<u64> {
        let now = self.clock.now();
        self.deadlines
            .iter()
            .find(|(_, &dl)| now >= dl)
            .map(|(&b, _)| b)
    }

    /// Clear everything (fault handling resets all in-flight state).
    pub fn clear(&mut self) {
        self.deadlines.clear();
    }

    pub fn armed(&self) -> usize {
        self.deadlines.len()
    }

    pub fn timeout(&self) -> Duration {
        self.timeout
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::VirtualClock;
    use std::sync::Arc;

    fn virt(timeout_ms: u64) -> (FaultDetector, Arc<VirtualClock>) {
        let clock = VirtualClock::shared();
        (FaultDetector::with_clock(Duration::from_millis(timeout_ms), clock.clone()), clock)
    }

    #[test]
    fn arms_and_disarms() {
        let mut d = FaultDetector::new(Duration::from_secs(60));
        d.arm(3);
        d.arm(4);
        assert_eq!(d.armed(), 2);
        assert_eq!(d.overdue(), None);
        d.disarm(3);
        assert_eq!(d.armed(), 1);
    }

    #[test]
    fn detects_overdue_earliest_first() {
        let (mut d, clock) = virt(5);
        d.arm(7);
        d.arm(5);
        assert_eq!(d.overdue(), None, "nothing overdue before the timeout");
        clock.advance(Duration::from_millis(10));
        assert_eq!(d.overdue(), Some(5));
        d.clear();
        assert_eq!(d.overdue(), None);
    }

    #[test]
    fn deadline_is_exact_on_the_virtual_timeline() {
        let (mut d, clock) = virt(100);
        clock.advance(Duration::from_millis(40));
        d.arm(0);
        clock.advance(Duration::from_millis(99));
        assert_eq!(d.overdue(), None, "one tick before the deadline");
        clock.advance(Duration::from_millis(1));
        assert_eq!(d.overdue(), Some(0), "exactly at the deadline");
    }

    #[test]
    fn multiple_simultaneously_overdue_batches_report_lowest_id() {
        // Batches armed at different times can all be overdue at once
        // (silence after a device death). The handler must see the
        // lowest batch id regardless of arming order.
        let (mut d, clock) = virt(50);
        d.arm(9);
        clock.advance(Duration::from_millis(10));
        d.arm(4);
        clock.advance(Duration::from_millis(10));
        d.arm(6);
        clock.advance(Duration::from_millis(200)); // all three overdue now
        assert_eq!(d.overdue(), Some(4));
        d.disarm(4);
        assert_eq!(d.overdue(), Some(6));
        d.disarm(6);
        assert_eq!(d.overdue(), Some(9));
    }

    #[test]
    fn recovery_clears_all_timers_and_rearms_fresh() {
        // clear-on-recovery: after the fault handler resets, re-armed
        // batches get fresh deadlines measured from the current time.
        let (mut d, clock) = virt(50);
        d.arm(1);
        d.arm(2);
        clock.advance(Duration::from_millis(60));
        assert_eq!(d.overdue(), Some(1));
        d.clear();
        assert_eq!(d.armed(), 0);
        d.arm(1); // replay after recovery
        assert_eq!(d.overdue(), None, "re-armed batch starts a fresh window");
        clock.advance(Duration::from_millis(49));
        assert_eq!(d.overdue(), None);
        clock.advance(Duration::from_millis(1));
        assert_eq!(d.overdue(), Some(1));
    }

    #[test]
    fn disarm_before_deadline_never_fires() {
        let (mut d, clock) = virt(30);
        d.arm(0);
        clock.advance(Duration::from_millis(29));
        d.disarm(0);
        clock.advance(Duration::from_secs(3600));
        assert_eq!(d.overdue(), None);
        assert_eq!(d.armed(), 0);
    }

    #[test]
    fn rearming_a_batch_extends_its_deadline() {
        let (mut d, clock) = virt(50);
        d.arm(3);
        clock.advance(Duration::from_millis(40));
        d.arm(3); // re-sent (e.g. replay after case-1 recovery)
        clock.advance(Duration::from_millis(40));
        assert_eq!(d.overdue(), None, "deadline measured from the re-arm");
        clock.advance(Duration::from_millis(10));
        assert_eq!(d.overdue(), Some(3));
    }
}
