//! Fault tolerance (paper §III-F): failure detection via gradient
//! timeouts at the central node, worker probing, worker-list renumbering,
//! and the Algorithm-1 weight-redistribution planner.
//!
//! The protocol driver lives in [`crate::coordinator`]; this module holds
//! the pure logic plus the [`detector::FaultDetector`] timer table.

pub mod detector;
pub mod redistribute;

pub use detector::FaultDetector;
pub use redistribute::{
    plan_redistribution, renumber, renumber_worker_list, source_of_block, RedistPlan, Source,
};
