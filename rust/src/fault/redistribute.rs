//! Weight redistribution — the paper's **Algorithm 1** (§III-D/§III-F).
//!
//! Given the new partition, what each device currently holds, and which
//! old stages failed, compute where every needed block must be fetched
//! from: locally, from the (renumbered) peer that owns it, from this
//! device's own chain-replica store, or from the central node's global
//! backup.
//!
//! This is a pure function — the protocol (FetchWeights / Weights /
//! FetchDone / Commit) lives in the pipeline; the property tests in
//! `rust/tests/redistribution.rs` drive this logic through thousands of
//! random partitions and failure sets.

use std::collections::BTreeMap;

use crate::partition::Partition;

/// Where a needed block can be fetched from (stage indices in the NEW list).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Source {
    /// A stage in the new worker list (0 = central node).
    Stage(usize),
    /// This device already stores it as a chain replica of a failed peer.
    LocalBackup,
    /// Only the central node's global backup can serve it.
    CentralBackup,
}

/// The fetch plan for one device.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RedistPlan {
    /// Blocks of the new range already held locally (paper: `L_local`).
    pub local: Vec<usize>,
    /// target -> blocks to fetch from it (paper: `M_need`).
    pub need: BTreeMap<Source, Vec<usize>>,
}

impl RedistPlan {
    /// Blocks that require a network fetch.
    pub fn network_fetches(&self) -> usize {
        self.need
            .iter()
            .filter(|(s, _)| !matches!(s, Source::LocalBackup))
            .map(|(_, v)| v.len())
            .sum()
    }
}

/// New index of an old stage after dropping `failed` stages
/// (paper: decrement indices greater than the failed index).
pub fn renumber(old_stage: usize, failed: &[usize]) -> Option<usize> {
    if failed.contains(&old_stage) {
        return None;
    }
    Some(old_stage - failed.iter().filter(|&&f| f < old_stage).count())
}

/// Update the worker list after failures: drop failed stages, preserving
/// order (the paper's single- and multi-failure renumbering rules both
/// reduce to this).
pub fn renumber_worker_list(worker_list: &[usize], failed: &[usize]) -> Vec<usize> {
    worker_list
        .iter()
        .enumerate()
        .filter(|(s, _)| !failed.contains(s))
        .map(|(_, &d)| d)
        .collect()
}

fn owner_of(l: usize, p_cur: &Partition) -> usize {
    p_cur
        .iter()
        .position(|&(lo, hi)| (lo..=hi).contains(&l))
        .expect("block not covered by old partition")
}

/// Which source holds block `l` after `failed` old stages died
/// (paper Algorithm 1 lines 9-15, generalized to multiple failures).
///
/// * Owner alive -> its renumbered stage.
/// * Owner failed, its old next stage alive -> that stage (chain replica).
/// * Owner failed and was the LAST old stage -> central (stage 0), which
///   receives the last worker's chain backup (paper §III-E).
/// * Otherwise (owner and replica holder both dead) -> global backup.
pub fn source_of_block(l: usize, p_cur: &Partition, failed: &[usize]) -> Source {
    let owner = owner_of(l, p_cur);
    if let Some(new_idx) = renumber(owner, failed) {
        return Source::Stage(new_idx);
    }
    let n_old = p_cur.len();
    if owner + 1 < n_old {
        if let Some(new_idx) = renumber(owner + 1, failed) {
            return Source::Stage(new_idx);
        }
        return Source::CentralBackup;
    }
    Source::Stage(0)
}

/// Algorithm 1, from the point of view of one device.
///
/// * `held` — blocks actually in this device's parameter store right now
///   (its old range normally; empty for a freshly-restarted device).
/// * `i_new` — this device's stage in the new list.
/// * `i_cur_old` — this device's stage in the old list (None if it was
///   not part of the old pipeline).
pub fn plan_redistribution(
    p_new: &Partition,
    p_cur: &Partition,
    failed: &[usize],
    held: &[usize],
    i_new: usize,
    i_cur_old: Option<usize>,
) -> RedistPlan {
    let (start_new, end_new) = p_new[i_new];
    let n_old = p_cur.len();
    let mut plan = RedistPlan::default();
    for l in start_new..=end_new {
        if held.contains(&l) {
            plan.local.push(l);
            continue;
        }
        let mut src = source_of_block(l, p_cur, failed);
        if src == Source::Stage(i_new) {
            // The computed source is myself. Two cases:
            let owner_old = owner_of(l, p_cur);
            if Some(owner_old) == i_cur_old {
                // (a) I owned it but lost my state (restart): fetch from MY
                //     chain-replica holder — old next stage, or central if
                //     I was the last stage.
                src = if owner_old + 1 < n_old {
                    match renumber(owner_old + 1, failed) {
                        Some(s) if s != i_new => Source::Stage(s),
                        _ => Source::CentralBackup,
                    }
                } else {
                    Source::Stage(0)
                };
            } else {
                // (b) the owner failed and I am its chain-replica holder:
                //     the weights are already in my backup store.
                src = Source::LocalBackup;
            }
        }
        plan.need.entry(src).or_default().push(l);
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renumber_shifts_above_failed() {
        // 4 stages, stage 1 fails
        assert_eq!(renumber(0, &[1]), Some(0));
        assert_eq!(renumber(1, &[1]), None);
        assert_eq!(renumber(2, &[1]), Some(1));
        assert_eq!(renumber(3, &[1]), Some(2));
        // two failures
        assert_eq!(renumber(3, &[0, 2]), Some(1));
    }

    #[test]
    fn renumber_worker_list_drops_failed_stages() {
        assert_eq!(renumber_worker_list(&[10, 11, 12, 13], &[1]), vec![10, 12, 13]);
        assert_eq!(renumber_worker_list(&[10, 11, 12, 13], &[1, 3]), vec![10, 12]);
        assert_eq!(renumber_worker_list(&[10, 11], &[]), vec![10, 11]);
    }

    #[test]
    fn alive_owner_with_index_correction() {
        // paper's first rule: I_target > I_fail => I_target - 1
        let p_cur = vec![(0, 3), (4, 7), (8, 11)];
        assert_eq!(source_of_block(9, &p_cur, &[1]), Source::Stage(1)); // old 2 -> new 1
        assert_eq!(source_of_block(0, &p_cur, &[1]), Source::Stage(0)); // below failed: unchanged
    }

    #[test]
    fn failed_owner_chain_replica_on_next() {
        // paper's rule: I_target == I_fail (not last) => index unchanged,
        // because old stage I_fail+1 (the replica holder) renumbers to I_fail.
        let p_cur = vec![(0, 3), (4, 7), (8, 11)];
        assert_eq!(source_of_block(5, &p_cur, &[1]), Source::Stage(1));
    }

    #[test]
    fn failed_last_stage_backup_at_central() {
        // paper's special case: last stage fails => fetch from stage 0
        let p_cur = vec![(0, 3), (4, 7), (8, 11)];
        assert_eq!(source_of_block(9, &p_cur, &[2]), Source::Stage(0));
    }

    #[test]
    fn two_adjacent_failures_fall_back_to_global_backup() {
        let p_cur = vec![(0, 2), (3, 5), (6, 8), (9, 11)];
        // stage 1 and its replica holder stage 2 both die
        assert_eq!(source_of_block(4, &p_cur, &[1, 2]), Source::CentralBackup);
        // stage 2's own blocks: replica on stage 3 (alive) -> new index 1
        assert_eq!(source_of_block(7, &p_cur, &[1, 2]), Source::Stage(1));
    }

    #[test]
    fn replica_holder_serves_failed_peer_blocks_from_local_backup() {
        // 4 stages, stage 1 dies; I am old stage 2 (new stage 1) and I hold
        // stage 1's chain replica: its blocks must come from my LOCAL store.
        let p_cur = vec![(0, 2), (3, 5), (6, 8), (9, 11)];
        let p_new = vec![(0, 3), (4, 7), (8, 11)];
        let plan =
            plan_redistribution(&p_new, &p_cur, &[1], &[6, 7, 8], 1, Some(2));
        assert_eq!(plan.local, vec![6, 7]);
        assert_eq!(plan.need.get(&Source::LocalBackup), Some(&vec![4, 5]));
        assert_eq!(plan.network_fetches(), 0);
    }

    #[test]
    fn restarted_device_fetches_own_range_from_replica_holder() {
        // paper case 2: device restarts with empty state, partition unchanged
        let p = vec![(0, 3), (4, 7), (8, 11)];
        let plan = plan_redistribution(&p, &p, &[], &[], 1, Some(1));
        assert!(plan.local.is_empty());
        // its own blocks must come from its chain-replica holder: stage 2
        assert_eq!(plan.need.get(&Source::Stage(2)), Some(&vec![4, 5, 6, 7]));
    }

    #[test]
    fn restarted_last_stage_fetches_from_central() {
        let p = vec![(0, 3), (4, 7), (8, 11)];
        let plan = plan_redistribution(&p, &p, &[], &[], 2, Some(2));
        assert_eq!(plan.need.get(&Source::Stage(0)), Some(&vec![8, 9, 10, 11]));
    }

    #[test]
    fn dynamic_repartition_no_failure() {
        // pure dynamic re-partition: fetch from current owners, no correction
        let p_cur = vec![(0, 5), (6, 8), (9, 11)];
        let p_new = vec![(0, 3), (4, 9), (10, 11)];
        let plan =
            plan_redistribution(&p_new, &p_cur, &[], &[6, 7, 8], 1, Some(1));
        assert_eq!(plan.local, vec![6, 7, 8]);
        assert_eq!(plan.need.get(&Source::Stage(0)), Some(&vec![4, 5]));
        assert_eq!(plan.need.get(&Source::Stage(2)), Some(&vec![9]));
    }

    #[test]
    fn central_gains_blocks_after_last_stage_failure() {
        // last stage dies; central (new stage 0) absorbs some of its blocks,
        // which it serves from the chain backup it received (Stage(0) = self
        // -> but owner_old(2) != i_cur_old(0) -> LocalBackup).
        let p_cur = vec![(0, 3), (4, 7), (8, 11)];
        let p_new = vec![(0, 5), (6, 11)];
        let plan =
            plan_redistribution(&p_new, &p_cur, &[2], &[0, 1, 2, 3], 0, Some(0));
        assert_eq!(plan.local, vec![0, 1, 2, 3]);
        assert_eq!(plan.need.get(&Source::Stage(1)), Some(&vec![4, 5]));
    }
}
