//! Run records: per-batch / per-epoch metrics, event log, JSON/CSV export.

use std::time::Duration;

use crate::sim::clock::{real_clock, SharedClock};
use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct BatchRecord {
    pub batch: u64,
    pub loss: f32,
    pub train_acc: f32,
    /// completion-to-completion interval ("time of training one batch",
    /// what the paper's Fig. 6 plots).
    pub wall_ms: f64,
    /// seconds since run start at completion.
    pub at_s: f64,
}

#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: u64,
    pub train_acc: f32,
    pub val_loss: f32,
    pub val_acc: f32,
    pub at_s: f64,
}

#[derive(Debug, Clone)]
pub struct Event {
    pub at_s: f64,
    pub kind: String,
}

/// Everything a training run produces (benches consume this directly).
#[derive(Debug, Default)]
pub struct RunRecord {
    pub batches: Vec<BatchRecord>,
    pub epochs: Vec<EpochRecord>,
    pub events: Vec<Event>,
    pub partitions: Vec<(u64, Vec<(usize, usize)>)>, // (batch, ranges)
    pub total_s: f64,
    pub net_bytes: u64,
    /// recovery overhead in seconds, when a fault was handled (Table III)
    pub recovery_overhead_s: Option<f64>,
    /// The coordinator phase machine's transition log (one line per
    /// observable transition, `coordinator::core` format) — the same
    /// artifact the deterministic harness exposes as
    /// `ScenarioOutcome::phase_log`, so conformance tests can compare
    /// the two drivers. Not serialized by `to_json`.
    pub phase_log: Vec<String>,
}

impl RunRecord {
    pub fn final_loss(&self) -> Option<f32> {
        self.batches.last().map(|b| b.loss)
    }

    pub fn mean_batch_ms(&self, from: u64, to: u64) -> Option<f64> {
        let xs: Vec<f64> = self
            .batches
            .iter()
            .filter(|b| b.batch >= from && b.batch <= to)
            .map(|b| b.wall_ms)
            .collect();
        (!xs.is_empty()).then(|| xs.iter().sum::<f64>() / xs.len() as f64)
    }

    pub fn event(&mut self, clock: &RunClock, kind: impl Into<String>) {
        self.events.push(Event { at_s: clock.now_s(), kind: kind.into() });
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("total_s", Value::Num(self.total_s)),
            ("net_bytes", Value::Num(self.net_bytes as f64)),
            (
                "recovery_overhead_s",
                self.recovery_overhead_s.map(Value::Num).unwrap_or(Value::Null),
            ),
            (
                "batches",
                Value::Arr(
                    self.batches
                        .iter()
                        .map(|b| {
                            Value::obj(vec![
                                ("batch", Value::Num(b.batch as f64)),
                                ("loss", Value::Num(b.loss as f64)),
                                ("train_acc", Value::Num(b.train_acc as f64)),
                                ("wall_ms", Value::Num(b.wall_ms)),
                                ("at_s", Value::Num(b.at_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "epochs",
                Value::Arr(
                    self.epochs
                        .iter()
                        .map(|e| {
                            Value::obj(vec![
                                ("epoch", Value::Num(e.epoch as f64)),
                                ("train_acc", Value::Num(e.train_acc as f64)),
                                ("val_loss", Value::Num(e.val_loss as f64)),
                                ("val_acc", Value::Num(e.val_acc as f64)),
                                ("at_s", Value::Num(e.at_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "events",
                Value::Arr(
                    self.events
                        .iter()
                        .map(|e| {
                            Value::obj(vec![
                                ("at_s", Value::Num(e.at_s)),
                                ("kind", Value::Str(e.kind.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn batches_csv(&self) -> String {
        let mut s = String::from("batch,loss,train_acc,wall_ms,at_s\n");
        for b in &self.batches {
            s.push_str(&format!(
                "{},{},{},{:.3},{:.3}\n",
                b.batch, b.loss, b.train_acc, b.wall_ms, b.at_s
            ));
        }
        s
    }
}

/// Run-relative clock: elapsed time since the run started, measured on
/// the [`crate::sim::Clock`] seam (wall time by default; a virtual
/// timeline under the scenario runner).
#[derive(Debug, Clone)]
pub struct RunClock {
    clock: SharedClock,
    start: Duration,
}

impl RunClock {
    pub fn start() -> RunClock {
        RunClock::on(real_clock())
    }

    /// Start a run clock on an explicit time source.
    pub fn on(clock: SharedClock) -> RunClock {
        let start = clock.now();
        RunClock { clock, start }
    }

    /// Seconds since the run started.
    pub fn now_s(&self) -> f64 {
        self.now().as_secs_f64()
    }

    /// Elapsed time since the run started.
    pub fn now(&self) -> Duration {
        self.clock.now().saturating_sub(self.start)
    }

    /// Absolute time on the underlying clock (for deadline arithmetic).
    pub fn raw_now(&self) -> Duration {
        self.clock.now()
    }

    /// Sleep on the underlying clock (virtual-aware pauses).
    pub fn sleep(&self, d: Duration) {
        self.clock.sleep(d);
    }
}

impl Default for RunClock {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_batch_window() {
        let mut r = RunRecord::default();
        for b in 0..10u64 {
            r.batches.push(BatchRecord {
                batch: b,
                loss: 1.0,
                train_acc: 0.5,
                wall_ms: b as f64,
                at_s: b as f64,
            });
        }
        assert_eq!(r.mean_batch_ms(2, 4), Some(3.0));
        assert_eq!(r.mean_batch_ms(100, 200), None);
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let mut r = RunRecord::default();
        r.total_s = 1.5;
        r.batches
            .push(BatchRecord { batch: 0, loss: 2.0, train_acc: 0.1, wall_ms: 3.0, at_s: 0.1 });
        r.events.push(Event { at_s: 0.5, kind: "fault".into() });
        let text = r.to_json().to_pretty();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("total_s").unwrap().as_f64(), Some(1.5));
        assert_eq!(
            v.get("events").unwrap().as_arr().unwrap()[0]
                .get("kind")
                .unwrap()
                .as_str(),
            Some("fault")
        );
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut r = RunRecord::default();
        r.batches
            .push(BatchRecord { batch: 1, loss: 0.5, train_acc: 0.9, wall_ms: 2.5, at_s: 1.0 });
        let csv = r.batches_csv();
        assert!(csv.starts_with("batch,loss"));
        assert_eq!(csv.lines().count(), 2);
    }
}
