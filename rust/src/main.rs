//! FTPipeHD command-line entrypoint.
//!
//! ```text
//! ftpipehd train --model artifacts/edgenet --devices 3 --capacities 1,2.5,10 \
//!                --epochs 1 --batches 50 [--engine ftpipehd|pipedream|respipe|single|sync]
//! ftpipehd profile --model artifacts/edgenet           per-block T^0_j table
//! ftpipehd partition --model ... --capacities 1,1,10   show DP cuts vs uniform
//! ftpipehd check-artifacts <dir>                       AOT bridge smoke test
//! ftpipehd central|worker --addrs a:p,b:p --rank N     multi-process TCP mode
//! ```

use anyhow::{bail, Context, Result};
use ftpipehd::cli::Args;
use ftpipehd::config::{DeviceConfig, Engine, RunConfig};
use ftpipehd::coordinator;
use ftpipehd::manifest::{Dtype, Manifest};
use ftpipehd::partition::{homogeneous_partition, optimal_partition, CostModel};
use ftpipehd::profile::profile_model;
use ftpipehd::runtime::{self, Engine as XlaEngine, HostTensor};

fn main() -> Result<()> {
    ftpipehd::util::logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("profile") => cmd_profile(&args),
        Some("partition") => cmd_partition(&args),
        Some("check-artifacts") => cmd_check(&args),
        Some("worker") => cmd_tcp(&args, false),
        Some("central") => cmd_tcp(&args, true),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    }
}

fn print_help() {
    println!(
        "ftpipehd — fault-tolerant pipeline-parallel training for heterogeneous edge devices\n\
         \n\
         USAGE:\n\
         \x20 ftpipehd train --model <dir> [--devices N] [--capacities 1,2.5,10]\n\
         \x20          [--bandwidth-mbps 12.5] [--epochs E] [--batches B] [--eval-batches K]\n\
         \x20          [--engine ftpipehd|pipedream|respipe|single|sync] [--lr 0.05]\n\
         \x20          [--kill-device I --kill-at-batch B [--restarts]] [--seed S] [--verbose]\n\
         \x20          [--out record.json]\n\
         \x20 ftpipehd profile --model <dir> [--reps 10]\n\
         \x20 ftpipehd partition --model <dir> --capacities 1,1,10 [--bandwidth-mbps 12.5]\n\
         \x20 ftpipehd check-artifacts <dir>\n\
         \x20 ftpipehd central --model <dir> --addrs 127.0.0.1:7000,127.0.0.1:7001 [...]\n\
         \x20 ftpipehd worker  --addrs ... --rank N --model <dir>\n\
         \n\
         TCP tuning (central/worker): [--config run.json] [--patient]\n\
         \x20          [--net-connect-attempts N] [--net-connect-backoff-ms N]\n\
         \x20          [--net-connect-timeout-ms N] [--net-down-ttl-ms N]\n\
         \n\
         env: FTPIPEHD_LOG=error|warn|info|debug|trace"
    );
}

/// Build a RunConfig from CLI flags.
fn config_from_args(args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    if let Some(m) = args.get("model") {
        cfg.model_dir = m.to_string();
    }
    let caps = args
        .get_f64_list("capacities")?
        .unwrap_or_else(|| vec![1.0; args.get_usize("devices", 3).unwrap_or(3)]);
    cfg.devices = caps.iter().map(|&c| DeviceConfig::with_capacity(c)).collect();
    if let Some(noise) = args.get("noise") {
        let v: f64 = noise.parse().context("--noise")?;
        for d in cfg.devices.iter_mut().skip(1) {
            d.noise = v;
        }
    }
    if let Some(bw) = args.get_f64_list("bandwidth-mbps")? {
        cfg.bandwidth_bps = bw.iter().map(|x| x * 1e6).collect();
    }
    cfg.lr = args.get_f64("lr", cfg.lr as f64)? as f32;
    cfg.epochs = args.get_usize("epochs", 1)?;
    cfg.batches_per_epoch = args.get_usize("batches", 50)?;
    cfg.eval_batches = args.get_usize("eval-batches", 5)?;
    cfg.seed = args.get_u64("seed", 0)?;
    cfg.verbose = args.get_bool("verbose");
    cfg.fault_timeout_ms = args.get_u64("fault-timeout-ms", 15_000)?;
    cfg.engine = match args.get("engine").unwrap_or("ftpipehd") {
        "ftpipehd" => Engine::FtPipeHd,
        "pipedream" => Engine::PipeDream,
        "respipe" => Engine::ResPipe,
        "single" => Engine::SingleDevice,
        "sync" => Engine::SyncPipeline,
        other => bail!("unknown engine {other:?}"),
    };
    if cfg.engine == Engine::SingleDevice {
        cfg.devices.truncate(1);
    }
    if let Some(kill) = args.get("kill-device") {
        cfg.fault = Some(ftpipehd::config::FaultPlan {
            kill_device: kill.parse().context("--kill-device")?,
            at_batch: args.get_u64("kill-at-batch", 20)?,
            restarts: args.get_bool("restarts"),
        });
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let record = coordinator::run_sim(&cfg)?;
    println!("=== run summary ===");
    println!("batches completed : {}", record.batches.len());
    if let Some(l) = record.final_loss() {
        println!("final loss        : {l:.4}");
    }
    for e in &record.epochs {
        println!(
            "epoch {}: train_acc={:.3} val_loss={:.4} val_acc={:.3}",
            e.epoch, e.train_acc, e.val_loss, e.val_acc
        );
    }
    println!("total time        : {:.2}s", record.total_s);
    println!("network bytes     : {}", record.net_bytes);
    if let Some(r) = record.recovery_overhead_s {
        println!("recovery overhead : {r:.3}s");
    }
    for ev in &record.events {
        println!("  [{:>8.2}s] {}", ev.at_s, ev.kind);
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, record.to_json().to_pretty())?;
        println!("record written to {out}");
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let dir = args.get("model").unwrap_or("artifacts/edgenet");
    let reps = args.get_usize("reps", 10)?;
    let manifest = Manifest::load(dir)?;
    let engine = XlaEngine::cpu()?;
    let blocks = runtime::load_all_blocks(&engine, &manifest)?;
    let prof = profile_model(&manifest, &blocks, reps)?;
    println!("block | name        | T0 fwd+bwd (ms) | out KiB | params KiB");
    for (i, b) in manifest.blocks.iter().enumerate() {
        println!(
            "{:>5} | {:<11} | {:>15.2} | {:>7.1} | {:>9.1}",
            i,
            b.name,
            prof.t0_ms[i],
            b.out_bytes as f64 / 1024.0,
            b.param_bytes as f64 / 1024.0
        );
    }
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let dir = args.get("model").unwrap_or("artifacts/edgenet");
    let caps = args
        .get_f64_list("capacities")?
        .unwrap_or_else(|| vec![1.0, 1.0, 1.0]);
    let bw = args.get_f64("bandwidth-mbps", 12.5)? * 1e6;
    let manifest = Manifest::load(dir)?;
    let engine = XlaEngine::cpu()?;
    let blocks = runtime::load_all_blocks(&engine, &manifest)?;
    let prof = profile_model(&manifest, &blocks, 5)?;
    let cm = CostModel {
        t0_ms: prof.t0_ms,
        out_bytes: prof.out_bytes,
        bandwidth_bps: vec![bw; caps.len() - 1],
        capacities: caps,
    };
    let (opt, opt_cost) = optimal_partition(&cm);
    let (blind, blind_cost) = homogeneous_partition(&cm);
    println!("capacity-aware partition : {opt:?}  bottleneck={opt_cost:.2}ms");
    println!("capacity-blind partition : {blind:?}  bottleneck={blind_cost:.2}ms");
    println!("speedup from dynamic partitioning: {:.2}x", blind_cost / opt_cost);
    Ok(())
}

/// Load every artifact of a compiled model, run one forward/backward chain
/// with the shipped initial weights, and print the resulting loss. This is
/// the fastest way to validate the python -> rust AOT bridge end to end.
fn cmd_check(args: &Args) -> Result<()> {
    let dir = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .or_else(|| args.get("model"))
        .unwrap_or("artifacts/edgenet-tiny");
    let m = Manifest::load(dir)?;
    println!(
        "model={} blocks={} params={} batch={}",
        m.model,
        m.n_blocks(),
        m.param_count,
        m.batch_size
    );
    let engine = XlaEngine::cpu()?;
    let blocks = runtime::load_all_blocks(&engine, &m)?;
    println!("compiled {} blocks", blocks.len());

    let params: Vec<Vec<Vec<f32>>> = (0..m.n_blocks())
        .map(|i| m.load_init_params(i))
        .collect::<Result<_>>()?;
    let in_elems: usize = m.input_shape.iter().product();
    let input = match m.input_dtype {
        Dtype::F32 => {
            HostTensor::F32((0..in_elems).map(|i| ((i % 17) as f32) * 0.1 - 0.8).collect())
        }
        Dtype::I32 => HostTensor::I32((0..in_elems).map(|i| (i % 7) as i32).collect()),
    };
    let lab_elems: usize = m.label_shape.iter().product();
    let labels = HostTensor::I32((0..lab_elems).map(|i| (i % 3) as i32).collect());

    let mut acts: Vec<HostTensor> = vec![input];
    for (i, b) in blocks.iter().enumerate().take(m.n_blocks() - 1) {
        let y = b.forward(&params[i], acts.last().unwrap())?;
        acts.push(HostTensor::F32(y.into()));
    }
    let head = blocks.last().unwrap();
    let x = acts.last().unwrap().as_f32()?.to_vec();
    let out = head.head_step(&params[m.n_blocks() - 1], &x, &labels, &m.label_shape)?;
    println!("head step: loss={:.4} ncorrect={}", out.loss, out.ncorrect);
    let mut gy = out.grad_input;
    for i in (0..m.n_blocks() - 1).rev() {
        let (grads, gx) = blocks[i].backward(&params[i], &acts[i], &gy)?;
        let gnorm: f32 = grads.iter().flatten().map(|g| g * g).sum::<f32>().sqrt();
        println!("block {i} bwd: grad-norm={gnorm:.4}");
        match gx {
            Some(g) => gy = g,
            None => break,
        }
    }
    println!("check-artifacts OK");
    Ok(())
}

/// TCP transport tuning: start from `--config <json>`'s `"net"` section
/// (or the `--patient` preset, or the defaults), then apply per-flag
/// millisecond overrides on top via the builder.
fn net_config_from_args(args: &Args) -> Result<ftpipehd::net::TcpConfig> {
    use ftpipehd::net::TcpConfig;
    let base = match args.get("config") {
        Some(path) => RunConfig::load(path)?.net,
        None if args.get_bool("patient") => TcpConfig::patient(),
        None => TcpConfig::default(),
    };
    let mut b = base.to_builder();
    if let Some(n) = args.get("net-connect-attempts") {
        b = b.connect_attempts(n.parse().context("--net-connect-attempts")?);
    }
    b = b.connect_backoff(
        args.get_duration_ms("net-connect-backoff-ms", base.connect_backoff())?,
    );
    b = b.connect_timeout(
        args.get_duration_ms("net-connect-timeout-ms", base.connect_timeout())?,
    );
    b = b.down_ttl(args.get_duration_ms("net-down-ttl-ms", base.down_ttl())?);
    Ok(b.build())
}

/// Multi-process TCP deployment (real distributed mode).
fn cmd_tcp(args: &Args, is_central: bool) -> Result<()> {
    use ftpipehd::net::TcpEndpoint;

    let addrs: Vec<String> = args
        .get("addrs")
        .context("--addrs a:port,b:port,... required")?
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let rank = if is_central { 0 } else { args.get_usize("rank", 1)? };
    let model_dir = args.get("model").unwrap_or("artifacts/edgenet-tiny");
    let manifest = std::sync::Arc::new(Manifest::load(model_dir)?);
    let net_cfg = net_config_from_args(args)?;
    let ep = TcpEndpoint::bind_with(rank, addrs.clone(), net_cfg, ftpipehd::sim::real_clock())?;

    if is_central {
        bail!(
            "TCP central mode: drive with the library API (see \
             rust/tests/tcp_pipeline.rs for the two-process harness); the \
             sim coordinator covers the full protocol in-process"
        );
    }
    println!("worker rank {rank} listening on {}", addrs[rank]);
    let engine = XlaEngine::cpu()?;
    let blocks = runtime::load_all_blocks(&engine, &manifest)?;
    let sim = ftpipehd::device::SimDevice::new(DeviceConfig::default(), rank as u64);
    let w = ftpipehd::pipeline::StageWorker::new(rank, manifest, blocks, sim, None);
    ftpipehd::pipeline::run_worker(w, Box::new(ep), None)?;
    Ok(())
}
