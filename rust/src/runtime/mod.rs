//! PJRT runtime: load AOT-compiled HLO text and execute it from Rust.
//!
//! This is the only module that touches the `xla` crate. Everything above
//! it exchanges shared [`TensorBuf`] / `Vec<i32>` host buffers (exactly
//! what travels over the — simulated or real — network between devices);
//! parameter tensors enter generically as `AsRef<[f32]>`, so both owned
//! init weights and shared `TensorBuf`-backed stage params feed XLA
//! without conversion copies.
//!
//! Threading: `PjRtClient` is `Rc`-based (not `Send`), so each simulated
//! device thread owns its own [`Engine`] and compiles its own block
//! executables. See DESIGN.md §4 "Runtime threading".

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::manifest::{BlockInfo, BlockKind, Dtype, Manifest};
use crate::net::TensorBuf;

pub mod native;

/// A host-side tensor (activation or label) as moved between devices.
/// The f32 arm is a shared buffer: cloning a `HostTensor` to stash an
/// activation for the backward pass costs a refcount bump, not a copy.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(TensorBuf),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => bail!("expected i32 tensor"),
        }
    }
}

fn dims_i64(shape: &[usize]) -> Vec<i64> {
    shape.iter().map(|&d| d as i64).collect()
}

fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(&dims_i64(shape))?)
}

fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(&dims_i64(shape))?)
}

fn literal_of(t: &HostTensor, shape: &[usize]) -> Result<xla::Literal> {
    match t {
        HostTensor::F32(v) => literal_f32(v, shape),
        HostTensor::I32(v) => literal_i32(v, shape),
    }
}

/// A compiled HLO module plus its output arity.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative wall time spent in `run` (profiling hook).
    pub exec_nanos: std::cell::Cell<u64>,
    pub exec_count: std::cell::Cell<u64>,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple()?;
        self.exec_nanos
            .set(self.exec_nanos.get() + t0.elapsed().as_nanos() as u64);
        self.exec_count.set(self.exec_count.get() + 1);
        Ok(out)
    }
}

/// Per-thread PJRT engine: one CPU client + executable loader.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu()? })
    }

    /// Load + compile an HLO text file (the AOT interchange format).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            exec_nanos: std::cell::Cell::new(0),
            exec_count: std::cell::Cell::new(0),
        })
    }
}

/// Outputs of a fused head step (forward + loss + backward).
#[derive(Debug, Clone)]
pub struct HeadStepOut {
    pub grad_params: Vec<Vec<f32>>,
    pub grad_input: Vec<f32>,
    pub loss: f32,
    pub ncorrect: f32,
}

/// The compiled artifacts of one block, bound to one engine/thread —
/// or a built-in native op (scenario fixtures, see [`native`]).
pub struct BlockRuntime {
    pub info: BlockInfo,
    fwd: Option<Executable>,
    bwd: Option<Executable>,
    step: Option<Executable>,
    eval: Option<Executable>,
    native: Option<native::NativeBlock>,
}

impl BlockRuntime {
    /// Compile all artifacts of block `info` on `engine`. A block whose
    /// manifest entry names a native op never touches the engine.
    pub fn load(engine: &Engine, info: &BlockInfo) -> Result<BlockRuntime> {
        if info.native.is_some() {
            return Self::load_native(info);
        }
        let load = |p: &Option<std::path::PathBuf>| -> Result<Option<Executable>> {
            Ok(match p {
                Some(p) => Some(engine.load(p)?),
                None => None,
            })
        };
        Ok(BlockRuntime {
            info: info.clone(),
            fwd: load(&info.fwd)?,
            bwd: load(&info.bwd)?,
            step: load(&info.step)?,
            eval: load(&info.eval)?,
            native: None,
        })
    }

    /// Build a native-op block (no PJRT engine required).
    pub fn load_native(info: &BlockInfo) -> Result<BlockRuntime> {
        Ok(BlockRuntime {
            info: info.clone(),
            fwd: None,
            bwd: None,
            step: None,
            eval: None,
            native: Some(native::NativeBlock::from_info(info)?),
        })
    }

    fn param_literals<P: AsRef<[f32]>>(&self, params: &[P]) -> Result<Vec<xla::Literal>> {
        if params.len() != self.info.params.len() {
            bail!(
                "block {}: got {} param tensors, expected {}",
                self.info.index,
                params.len(),
                self.info.params.len()
            );
        }
        params
            .iter()
            .zip(&self.info.params)
            .map(|(p, pi)| {
                let p = p.as_ref();
                if p.len() != pi.size {
                    bail!(
                        "block {}: param size {} != manifest {}",
                        self.info.index,
                        p.len(),
                        pi.size
                    );
                }
                literal_f32(p, &pi.shape)
            })
            .collect()
    }

    /// Forward: (params, x) -> y.
    pub fn forward<P: AsRef<[f32]>>(&self, params: &[P], x: &HostTensor) -> Result<Vec<f32>> {
        if let Some(nb) = &self.native {
            return nb.forward(params, x);
        }
        let exe = self.fwd.as_ref().context("block has no fwd artifact")?;
        let mut inputs = self.param_literals(params)?;
        inputs.push(literal_of(x, &self.info.in_shape)?);
        let out = exe.run(&inputs)?;
        if out.len() != 1 {
            bail!("fwd returned {} outputs, expected 1", out.len());
        }
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Backward: (params, x, gy) -> (grad_params, grad_x if has_gx).
    pub fn backward<P: AsRef<[f32]>>(
        &self,
        params: &[P],
        x: &HostTensor,
        gy: &[f32],
    ) -> Result<(Vec<Vec<f32>>, Option<Vec<f32>>)> {
        if let Some(nb) = &self.native {
            return nb.backward(params, x, gy);
        }
        let exe = self.bwd.as_ref().context("block has no bwd artifact")?;
        let mut inputs = self.param_literals(params)?;
        inputs.push(literal_of(x, &self.info.in_shape)?);
        inputs.push(literal_f32(gy, &self.info.out_shape)?);
        let out = exe.run(&inputs)?;
        let np = self.info.params.len();
        let want = np + usize::from(self.info.has_gx);
        if out.len() != want {
            bail!("bwd returned {} outputs, expected {}", out.len(), want);
        }
        let mut grads = Vec::with_capacity(np);
        for lit in &out[..np] {
            grads.push(lit.to_vec::<f32>()?);
        }
        let gx = if self.info.has_gx {
            Some(out[np].to_vec::<f32>()?)
        } else {
            None
        };
        Ok((grads, gx))
    }

    /// Fused head step: (params, x, labels) -> grads + gx + loss + ncorrect.
    pub fn head_step<P: AsRef<[f32]>>(
        &self,
        params: &[P],
        x: &[f32],
        labels: &HostTensor,
        label_shape: &[usize],
    ) -> Result<HeadStepOut> {
        if let Some(nb) = &self.native {
            return nb.head_step(params, x, labels);
        }
        let exe = self.step.as_ref().context("block has no step artifact")?;
        let mut inputs = self.param_literals(params)?;
        inputs.push(literal_f32(x, &self.info.in_shape)?);
        inputs.push(literal_of(labels, label_shape)?);
        let out = exe.run(&inputs)?;
        let np = self.info.params.len();
        if out.len() != np + 3 {
            bail!("head step returned {} outputs, expected {}", out.len(), np + 3);
        }
        let mut grad_params = Vec::with_capacity(np);
        for lit in &out[..np] {
            grad_params.push(lit.to_vec::<f32>()?);
        }
        Ok(HeadStepOut {
            grad_params,
            grad_input: out[np].to_vec::<f32>()?,
            loss: out[np + 1].get_first_element::<f32>()?,
            ncorrect: out[np + 2].get_first_element::<f32>()?,
        })
    }

    /// Head eval: (params, x, labels) -> (loss, ncorrect).
    pub fn head_eval<P: AsRef<[f32]>>(
        &self,
        params: &[P],
        x: &[f32],
        labels: &HostTensor,
        label_shape: &[usize],
    ) -> Result<(f32, f32)> {
        if let Some(nb) = &self.native {
            return nb.head_eval(params, x, labels);
        }
        let exe = self.eval.as_ref().context("block has no eval artifact")?;
        let mut inputs = self.param_literals(params)?;
        inputs.push(literal_f32(x, &self.info.in_shape)?);
        inputs.push(literal_of(labels, label_shape)?);
        let out = exe.run(&inputs)?;
        if out.len() != 2 {
            bail!("head eval returned {} outputs, expected 2", out.len());
        }
        Ok((
            out[0].get_first_element::<f32>()?,
            out[1].get_first_element::<f32>()?,
        ))
    }

    pub fn is_head(&self) -> bool {
        self.info.kind == BlockKind::Head
    }
}

/// Compile every block of `manifest` on a fresh engine (one per thread).
pub fn load_all_blocks(engine: &Engine, manifest: &Manifest) -> Result<Vec<BlockRuntime>> {
    manifest
        .blocks
        .iter()
        .map(|b| BlockRuntime::load(engine, b))
        .collect()
}

/// Build every block of a fully-native manifest — no engine, no PJRT.
/// Errors if any block lacks a native op (mixed manifests must go
/// through [`load_all_blocks`]).
pub fn load_all_blocks_native(manifest: &Manifest) -> Result<Vec<BlockRuntime>> {
    manifest.blocks.iter().map(BlockRuntime::load_native).collect()
}

/// Build the HostTensor for an input/label buffer given the manifest dtype.
pub fn host_tensor(dtype: Dtype, f32s: Option<Vec<f32>>, i32s: Option<Vec<i32>>) -> HostTensor {
    match dtype {
        Dtype::F32 => HostTensor::F32(f32s.expect("f32 payload").into()),
        Dtype::I32 => HostTensor::I32(i32s.expect("i32 payload")),
    }
}
