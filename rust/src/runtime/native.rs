//! Built-in pure-Rust block ops for the deterministic scenario fixtures.
//!
//! The vendored `xla` crate is a stub (DESIGN.md §4), so the fault
//! scenarios in `rust/tests/scenarios/` — which must run real
//! forward/backward/SGD math to assert weight equality across recoveries
//! — execute these native ops instead of HLO artifacts. A manifest block
//! with `"native": "affine"` or `"native": "head"` is dispatched here by
//! [`super::BlockRuntime`]; everything above the runtime (pipeline,
//! replication, redistribution, coordinator) is byte-for-byte the same
//! code that runs compiled models.
//!
//! Semantics (shapes from the block's manifest entry):
//!
//! * `affine` — `y = x ⊙ scale + bias` over `[batch, dim]`, params
//!   `[scale(dim), bias(dim)]`. Gradients are exact; `grad_x` is emitted
//!   only when the manifest says `has_gx`.
//! * `head` — linear classifier + softmax cross-entropy over
//!   `[batch, dim] → classes`, params `[w(dim·classes), b(classes)]`.
//!
//! All loops run in a fixed order over plain `f32` — on one machine two
//! executions produce bit-identical results, which is the property the
//! scenario determinism assertions rely on.

use anyhow::{bail, Context, Result};

use crate::manifest::BlockInfo;

use super::{HeadStepOut, HostTensor};

/// A natively-executable block.
#[derive(Debug, Clone)]
pub enum NativeBlock {
    Affine { batch: usize, dim: usize, has_gx: bool },
    Head { batch: usize, dim: usize, classes: usize },
}

fn shape2(info: &BlockInfo) -> Result<(usize, usize)> {
    match info.in_shape[..] {
        [b, d] => Ok((b, d)),
        _ => bail!(
            "native block {}: in_shape {:?} is not [batch, dim]",
            info.index,
            info.in_shape
        ),
    }
}

impl NativeBlock {
    /// Build from a manifest entry whose `native` field is set.
    pub fn from_info(info: &BlockInfo) -> Result<NativeBlock> {
        let kind = info.native.as_deref().context("block has no native op")?;
        let (batch, dim) = shape2(info)?;
        match kind {
            "affine" => {
                Self::check_params(info, &[dim, dim])?;
                Ok(NativeBlock::Affine { batch, dim, has_gx: info.has_gx })
            }
            "head" => {
                let classes = match info.out_shape[..] {
                    [b, c] if b == batch => c,
                    _ => bail!(
                        "native head {}: out_shape {:?} is not [batch, classes]",
                        info.index,
                        info.out_shape
                    ),
                };
                Self::check_params(info, &[dim * classes, classes])?;
                Ok(NativeBlock::Head { batch, dim, classes })
            }
            other => bail!("unknown native op {other:?} for block {}", info.index),
        }
    }

    fn check_params(info: &BlockInfo, sizes: &[usize]) -> Result<()> {
        if info.params.len() != sizes.len()
            || info.params.iter().zip(sizes).any(|(p, &s)| p.size != s)
        {
            bail!(
                "native block {}: param sizes {:?} do not match expected {:?}",
                info.index,
                info.params.iter().map(|p| p.size).collect::<Vec<_>>(),
                sizes
            );
        }
        Ok(())
    }

    fn params_of<'a, P: AsRef<[f32]>>(
        &self,
        params: &'a [P],
        want: usize,
    ) -> Result<Vec<&'a [f32]>> {
        if params.len() != want {
            bail!("native block: got {} param tensors, expected {want}", params.len());
        }
        Ok(params.iter().map(|p| p.as_ref()).collect())
    }

    /// Forward: (params, x) -> y.
    pub fn forward<P: AsRef<[f32]>>(&self, params: &[P], x: &HostTensor) -> Result<Vec<f32>> {
        let NativeBlock::Affine { batch, dim, .. } = self else {
            bail!("native head has no standalone forward (use head_step/head_eval)");
        };
        let p = self.params_of(params, 2)?;
        let (scale, bias) = (p[0], p[1]);
        let x = x.as_f32()?;
        let mut y = vec![0f32; batch * dim];
        for b in 0..*batch {
            for d in 0..*dim {
                let i = b * dim + d;
                y[i] = x[i] * scale[d] + bias[d];
            }
        }
        Ok(y)
    }

    /// Backward: (params, x, gy) -> (grad_params, grad_x if has_gx).
    pub fn backward<P: AsRef<[f32]>>(
        &self,
        params: &[P],
        x: &HostTensor,
        gy: &[f32],
    ) -> Result<(Vec<Vec<f32>>, Option<Vec<f32>>)> {
        let NativeBlock::Affine { batch, dim, has_gx } = self else {
            bail!("native head has no standalone backward (use head_step)");
        };
        let p = self.params_of(params, 2)?;
        let scale = p[0];
        let x = x.as_f32()?;
        let mut gs = vec![0f32; *dim];
        let mut gb = vec![0f32; *dim];
        for b in 0..*batch {
            for d in 0..*dim {
                let i = b * dim + d;
                gs[d] += gy[i] * x[i];
                gb[d] += gy[i];
            }
        }
        let gx = has_gx.then(|| {
            let mut gx = vec![0f32; batch * dim];
            for b in 0..*batch {
                for d in 0..*dim {
                    let i = b * dim + d;
                    gx[i] = gy[i] * scale[d];
                }
            }
            gx
        });
        Ok((vec![gs, gb], gx))
    }

    /// Logits + per-sample softmax probabilities (shared by step/eval).
    fn head_probs<P: AsRef<[f32]>>(
        &self,
        params: &[P],
        x: &[f32],
        labels: &HostTensor,
    ) -> Result<(Vec<f32>, Vec<i32>, f32, f32)> {
        let NativeBlock::Head { batch, dim, classes } = self else {
            bail!("affine block has no head step");
        };
        let p = self.params_of(params, 2)?;
        let (w, bias) = (p[0], p[1]);
        let labels = labels.as_i32()?.to_vec();
        if labels.len() != *batch {
            bail!("native head: {} labels for batch {batch}", labels.len());
        }
        let mut probs = vec![0f32; batch * classes];
        let mut loss = 0f64;
        let mut ncorrect = 0f32;
        for b in 0..*batch {
            let logits = &mut probs[b * classes..(b + 1) * classes];
            for (c, l) in logits.iter_mut().enumerate() {
                let mut acc = bias[c];
                for d in 0..*dim {
                    acc += x[b * dim + d] * w[d * classes + c];
                }
                *l = acc;
            }
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut argmax = 0usize;
            for (c, &l) in logits.iter().enumerate() {
                if l > logits[argmax] {
                    argmax = c;
                }
            }
            let mut z = 0f32;
            for l in logits.iter_mut() {
                *l = (*l - max).exp();
                z += *l;
            }
            for l in logits.iter_mut() {
                *l /= z;
            }
            let y = labels[b] as usize;
            if y >= *classes {
                bail!("native head: label {y} out of range (classes {classes})");
            }
            loss -= (logits[y].max(1e-12) as f64).ln();
            if argmax == y {
                ncorrect += 1.0;
            }
        }
        Ok((probs, labels, (loss / *batch as f64) as f32, ncorrect))
    }

    /// Fused head step: forward + loss + backward.
    pub fn head_step<P: AsRef<[f32]>>(
        &self,
        params: &[P],
        x: &[f32],
        labels: &HostTensor,
    ) -> Result<HeadStepOut> {
        let (probs, labels, loss, ncorrect) = self.head_probs(params, x, labels)?;
        let NativeBlock::Head { batch, dim, classes } = self else { unreachable!() };
        let p = self.params_of(params, 2)?;
        let w = p[0];
        // dlogits = (softmax - onehot) / batch
        let mut dlogits = probs;
        for b in 0..*batch {
            dlogits[b * classes + labels[b] as usize] -= 1.0;
        }
        let inv_b = 1.0 / *batch as f32;
        for g in dlogits.iter_mut() {
            *g *= inv_b;
        }
        let mut gw = vec![0f32; dim * classes];
        let mut gb = vec![0f32; *classes];
        let mut gx = vec![0f32; batch * dim];
        for b in 0..*batch {
            for c in 0..*classes {
                let dl = dlogits[b * classes + c];
                gb[c] += dl;
                for d in 0..*dim {
                    gw[d * classes + c] += x[b * dim + d] * dl;
                    gx[b * dim + d] += dl * w[d * classes + c];
                }
            }
        }
        Ok(HeadStepOut { grad_params: vec![gw, gb], grad_input: gx, loss, ncorrect })
    }

    /// Head eval: (params, x, labels) -> (loss, ncorrect).
    pub fn head_eval<P: AsRef<[f32]>>(
        &self,
        params: &[P],
        x: &[f32],
        labels: &HostTensor,
    ) -> Result<(f32, f32)> {
        let (_, _, loss, ncorrect) = self.head_probs(params, x, labels)?;
        Ok((loss, ncorrect))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn affine(batch: usize, dim: usize, has_gx: bool) -> NativeBlock {
        NativeBlock::Affine { batch, dim, has_gx }
    }

    fn head(batch: usize, dim: usize, classes: usize) -> NativeBlock {
        NativeBlock::Head { batch, dim, classes }
    }

    #[test]
    fn affine_forward_backward_exact() {
        let nb = affine(2, 2, true);
        let params = [vec![2.0f32, 3.0], vec![0.5, -0.5]];
        let x = HostTensor::F32(vec![1.0f32, 2.0, 3.0, 4.0].into());
        let y = nb.forward(&params, &x).unwrap();
        assert_eq!(y, vec![2.5, 5.5, 6.5, 11.5]);
        let gy = vec![1.0f32, 1.0, 1.0, 1.0];
        let (grads, gx) = nb.backward(&params, &x, &gy).unwrap();
        assert_eq!(grads[0], vec![4.0, 6.0]); // Σ x per column
        assert_eq!(grads[1], vec![2.0, 2.0]); // Σ gy per column
        assert_eq!(gx.unwrap(), vec![2.0, 3.0, 2.0, 3.0]); // gy * scale
    }

    #[test]
    fn affine_without_gx_omits_input_grad() {
        let nb = affine(1, 2, false);
        let params = [vec![1.0f32, 1.0], vec![0.0, 0.0]];
        let x = HostTensor::F32(vec![1.0f32, 2.0].into());
        let (_, gx) = nb.backward(&params, &x, &[1.0, 1.0]).unwrap();
        assert!(gx.is_none());
    }

    #[test]
    fn head_loss_and_grad_sanity() {
        let nb = head(2, 2, 2);
        // identity-ish weights: class = argmax over x dims
        let params = [vec![4.0f32, 0.0, 0.0, 4.0], vec![0.0, 0.0]];
        let x = vec![1.0f32, 0.0, 0.0, 1.0]; // sample 0 -> class 0, sample 1 -> class 1
        let labels = HostTensor::I32(vec![0, 1]);
        let out = nb.head_step(&params, &x, &labels).unwrap();
        assert_eq!(out.ncorrect, 2.0);
        assert!(out.loss > 0.0 && out.loss < 0.1, "loss={}", out.loss);
        let (eval_loss, eval_nc) = nb.head_eval(&params, &x, &labels).unwrap();
        assert_eq!(eval_nc, 2.0);
        assert!((eval_loss - out.loss).abs() < 1e-7);
        // gradient of a correct confident prediction is small but nonzero
        let gnorm: f32 = out.grad_params[0].iter().map(|g| g * g).sum::<f32>().sqrt();
        assert!(gnorm > 0.0 && gnorm < 0.1, "gnorm={gnorm}");
    }

    #[test]
    fn head_gradient_descends_loss() {
        let nb = head(4, 3, 2);
        let mut w = vec![0.01f32; 6];
        let mut b = vec![0.0f32; 2];
        let x: Vec<f32> = (0..12).map(|i| ((i % 5) as f32 - 2.0) * 0.3).collect();
        let labels = HostTensor::I32(vec![0, 1, 1, 0]);
        let params = [w.clone(), b.clone()];
        let first = nb.head_step(&params, &x, &labels).unwrap();
        for (wi, g) in w.iter_mut().zip(&first.grad_params[0]) {
            *wi -= 0.5 * g;
        }
        for (bi, g) in b.iter_mut().zip(&first.grad_params[1]) {
            *bi -= 0.5 * g;
        }
        let (after, _) = nb.head_eval(&[w, b], &x, &labels).unwrap();
        assert!(after < first.loss, "loss did not decrease: {} -> {after}", first.loss);
    }

    #[test]
    fn execution_is_bit_deterministic() {
        let nb = head(3, 4, 3);
        let params = [
            (0..12).map(|i| (i as f32) * 0.1 - 0.5).collect::<Vec<f32>>(),
            vec![0.1f32, -0.2, 0.3],
        ];
        let x: Vec<f32> = (0..12).map(|i| ((i * 7 % 11) as f32) * 0.13).collect();
        let labels = HostTensor::I32(vec![2, 0, 1]);
        let a = nb.head_step(&params, &x, &labels).unwrap();
        let b = nb.head_step(&params, &x, &labels).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        let bits = |v: &Vec<f32>| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.grad_input), bits(&b.grad_input));
        for (ga, gb) in a.grad_params.iter().zip(&b.grad_params) {
            assert_eq!(bits(ga), bits(gb));
        }
    }
}
