//! Synthetic native-model fixtures for the scenario suite.
//!
//! Writes a complete model directory — `manifest.json` + seeded initial
//! weights — whose blocks are the pure-Rust ops of
//! [`crate::runtime::native`], so the full training stack runs with no
//! PJRT backend and no `make artifacts`. Content is a pure function of
//! the [`FixtureSpec`], so two materializations (or two runs against one
//! directory) see identical bytes.
//!
//! Shape: `n_blocks - 1` affine blocks over `[batch, dim]` followed by a
//! linear+softmax head over `classes`. Per-block flop counts are staggered
//! so the partition DP has real structure to optimize over.

use std::path::Path;

use anyhow::{Context, Result};

use crate::manifest::Manifest;
use crate::util::rng::Rng;

/// Everything that determines a fixture's bytes.
#[derive(Debug, Clone)]
pub struct FixtureSpec {
    /// Total blocks including the head (>= 2).
    pub n_blocks: usize,
    pub dim: usize,
    pub classes: usize,
    pub batch: usize,
    pub seed: u64,
}

impl Default for FixtureSpec {
    fn default() -> FixtureSpec {
        FixtureSpec { n_blocks: 8, dim: 16, classes: 4, batch: 8, seed: 1 }
    }
}

/// Flop cost of block `i` (staggered: 1x/2x/3x a base unit, head 2x).
/// Referenced by both the manifest writer and tests that reason about
/// expected partitions.
pub fn block_flops(i: usize, n_blocks: usize) -> (u64, u64) {
    const BASE: u64 = 500_000;
    let fwd = if i + 1 == n_blocks { 2 * BASE } else { (1 + (i as u64 % 3)) * BASE };
    (fwd, 2 * fwd)
}

fn write_f32_le(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

/// Write the fixture under `dir` and load it back as a [`Manifest`].
/// Idempotent: re-materializing the same spec rewrites identical bytes.
pub fn materialize(dir: &Path, spec: &FixtureSpec) -> Result<Manifest> {
    assert!(spec.n_blocks >= 2, "need at least one affine block plus the head");
    let init_dir = dir.join("init");
    std::fs::create_dir_all(&init_dir)
        .with_context(|| format!("creating {}", init_dir.display()))?;
    let mut rng = Rng::new(spec.seed ^ 0xF1C7_0000);
    let (b, d, c) = (spec.batch, spec.dim, spec.classes);

    let mut blocks_json = Vec::new();
    let mut param_count = 0u64;
    for i in 0..spec.n_blocks {
        let head = i + 1 == spec.n_blocks;
        let mut block_rng = rng.fork(i as u64);
        let (flops_fwd, flops_bwd) = block_flops(i, spec.n_blocks);
        let (params, out_shape, native, kind) = if head {
            let w: Vec<f32> =
                (0..d * c).map(|_| (block_rng.normal() as f32) * 0.1).collect();
            let bias = vec![0f32; c];
            write_f32_le(&init_dir.join(format!("b{i}_w.bin")), &w)?;
            write_f32_le(&init_dir.join(format!("b{i}_b.bin")), &bias)?;
            // the head weight is a true 2-D [dim, classes] matrix (the
            // native head reads it row-major) — declared as such so the
            // wire layer's per-channel quantization sees the geometry
            let params = format!(
                r#"[{{"shape": [{d}, {c}], "size": {dc}, "init": "init/b{i}_w.bin"}},
                    {{"shape": [{c}], "size": {c}, "init": "init/b{i}_b.bin"}}]"#,
                dc = d * c,
            );
            (params, format!("[{b}, {c}]"), "head", "head")
        } else {
            let scale: Vec<f32> =
                (0..d).map(|_| 1.0 + (block_rng.normal() as f32) * 0.05).collect();
            let bias: Vec<f32> =
                (0..d).map(|_| (block_rng.normal() as f32) * 0.02).collect();
            write_f32_le(&init_dir.join(format!("b{i}_s.bin")), &scale)?;
            write_f32_le(&init_dir.join(format!("b{i}_b.bin")), &bias)?;
            let params = format!(
                r#"[{{"shape": [{d}], "size": {d}, "init": "init/b{i}_s.bin"}},
                    {{"shape": [{d}], "size": {d}, "init": "init/b{i}_b.bin"}}]"#,
            );
            (params, format!("[{b}, {d}]"), "affine", "block")
        };
        let param_elems = if head { d * c + c } else { 2 * d } as u64;
        param_count += param_elems;
        let out_bytes = if head { b * c * 4 } else { b * d * 4 };
        blocks_json.push(format!(
            r#"{{"index": {i}, "name": "{native}{i}", "kind": "{kind}", "native": "{native}",
  "params": {params},
  "in_shape": [{b}, {d}], "in_dtype": "f32", "out_shape": {out_shape},
  "flops_fwd": {flops_fwd}, "flops_bwd": {flops_bwd},
  "out_bytes": {out_bytes}, "param_bytes": {param_bytes},
  "has_gx": {has_gx}}}"#,
            param_bytes = param_elems * 4,
            has_gx = i != 0,
        ));
    }

    let manifest = format!(
        r#"{{
  "model": "sim-native-{seed}",
  "batch_size": {b},
  "input": {{"shape": [{b}, {d}], "dtype": "f32"}},
  "labels": {{"shape": [{b}], "dtype": "i32"}},
  "acc_denom": {b},
  "param_count": {param_count},
  "meta": {{"n_classes": {c}}},
  "blocks": [
{blocks}
  ]
}}"#,
        seed = spec.seed,
        blocks = blocks_json.join(",\n"),
    );
    std::fs::write(dir.join("manifest.json"), manifest)
        .with_context(|| format!("writing {}/manifest.json", dir.display()))?;
    Manifest::load(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::BlockKind;
    use crate::runtime::load_all_blocks_native;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ftpipehd-fixture-{name}-{}", std::process::id()))
    }

    #[test]
    fn materializes_and_loads_natively() {
        let dir = tmp("load");
        let spec = FixtureSpec::default();
        let m = materialize(&dir, &spec).expect("materialize");
        assert_eq!(m.n_blocks(), spec.n_blocks);
        assert_eq!(m.head().kind, BlockKind::Head);
        assert_eq!(m.n_classes, Some(spec.classes));
        assert_eq!(m.batch_size, spec.batch);
        let blocks = load_all_blocks_native(&m).expect("native blocks");
        assert_eq!(blocks.len(), spec.n_blocks);
        // init weights load with the declared shapes
        for i in 0..m.n_blocks() {
            let p = m.load_init_params(i).expect("init params");
            assert_eq!(p.len(), 2);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rematerialization_is_byte_identical() {
        let dir = tmp("bytes");
        let spec = FixtureSpec { seed: 42, ..FixtureSpec::default() };
        materialize(&dir, &spec).unwrap();
        let first = std::fs::read(dir.join("manifest.json")).unwrap();
        let w0 = std::fs::read(dir.join("init/b0_s.bin")).unwrap();
        materialize(&dir, &spec).unwrap();
        assert_eq!(first, std::fs::read(dir.join("manifest.json")).unwrap());
        assert_eq!(w0, std::fs::read(dir.join("init/b0_s.bin")).unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
