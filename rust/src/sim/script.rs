//! Declarative failure-scenario scripts.
//!
//! A [`Scenario`] is everything the deterministic runner needs: cluster
//! shape, training hyper-parameters, the virtual network/compute model,
//! and a list of [`ScriptEvent`]s — "kill worker 2 when batch 40
//! completes", "slow worker 1 by 10x at t=2s", "kill another worker the
//! moment redistribution #1 starts". Triggers are expressed against
//! *protocol state* (batches completed, redistributions started) or
//! virtual time, never wall time, so a script means the same thing on
//! every machine.
//!
//! See DESIGN.md §7 for how to add a new scenario.

use std::time::Duration;

use crate::net::message::DeviceId;

/// When a scripted action fires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Trigger {
    /// When batch `b` completes at the central node (before the next
    /// injection — the pipeline quiesces at this batch when inflight=1).
    BatchDone(u64),
    /// At an absolute virtual time.
    At(Duration),
    /// The moment the `n`-th redistribution (1-based) begins — i.e. the
    /// `Repartition` broadcast and `FetchWeights` requests are already
    /// in flight. This is the "failure during an in-flight
    /// redistribution" hook.
    RedistributionStart(usize),
}

/// What happens when a trigger fires.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Kill a worker (state wiped, traffic dropped both ways). With
    /// `revive_after`, the device comes back that much later with empty
    /// state — the paper's case-2 "restarts as soon as it failed".
    Kill { device: DeviceId, revive_after: Option<Duration> },
    /// Change a device's capacity factor (e.g. 10.0 = now 10x slower) —
    /// drives the dynamic re-partition path.
    SetCapacity { device: DeviceId, capacity: f64 },
}

#[derive(Debug, Clone)]
pub struct ScriptEvent {
    pub at: Trigger,
    pub action: Action,
}

/// A complete scenario: deterministic given these fields + the fixture.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    /// Capacity factor per device; index 0 is the central node (1.0).
    pub capacities: Vec<f64>,
    /// Total training batches to complete.
    pub batches: u64,
    pub seed: u64,

    // --- training hyper-parameters ---
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Max in-flight batches (1 = fully serialized, quiesces between
    /// batches — the setting under which recovery is *exact*).
    pub inflight: usize,
    /// Weight aggregation interval factor (0 disables).
    pub agg_k: u32,
    /// Chain/global replication periods in batches (0 disables).
    pub chain_every: u64,
    pub global_every: u64,

    // --- schedules ---
    /// Dynamic re-partition: (first at batch, then every) — None disables.
    pub repartition: Option<(u64, u64)>,
    /// Central-node gradient timeout (virtual).
    pub fault_timeout: Duration,
    /// How long the coordinator waits for probe acks (virtual).
    pub probe_window: Duration,
    /// How long a redistribution may stall before re-probing (virtual) —
    /// this is what makes a mid-redistribution failure recoverable.
    pub redist_window: Duration,

    // --- virtual network + compute model ---
    pub bandwidth_bps: f64,
    pub latency: Duration,
    /// Modeled compute cost; per-batch stage time = flops × this × C_i.
    pub ns_per_flop: f64,

    pub events: Vec<ScriptEvent>,
}

impl Scenario {
    /// A conservative base: 3 devices, serialized pipeline, replicate
    /// every batch, momentum off — the configuration under which
    /// recovery is mathematically exact (see `rust/tests/scenarios/`).
    pub fn exact_recovery(name: &str, n_devices: usize, batches: u64) -> Scenario {
        Scenario {
            name: name.to_string(),
            capacities: vec![1.0; n_devices],
            batches,
            seed: 7,
            lr: 0.05,
            momentum: 0.0,
            weight_decay: 0.0,
            inflight: 1,
            agg_k: 0,
            chain_every: 1,
            global_every: 1,
            repartition: None,
            fault_timeout: Duration::from_millis(200),
            probe_window: Duration::from_millis(50),
            redist_window: Duration::from_secs(2),
            bandwidth_bps: 1e8,
            latency: Duration::from_micros(100),
            ns_per_flop: 1.0,
            events: vec![],
        }
    }

    /// A pipelined base (inflight = n_stages, momentum on, aggregation
    /// on): realistic async-1F1B behavior; recovery is asserted for
    /// continuity + determinism rather than exact weight equality.
    pub fn pipelined(name: &str, n_devices: usize, batches: u64) -> Scenario {
        Scenario {
            momentum: 0.9,
            weight_decay: 4e-5,
            inflight: n_devices,
            agg_k: 4,
            chain_every: 5,
            global_every: 10,
            ..Scenario::exact_recovery(name, n_devices, batches)
        }
    }

    pub fn n_devices(&self) -> usize {
        self.capacities.len()
    }

    pub fn with_events(mut self, events: Vec<ScriptEvent>) -> Scenario {
        self.events = events;
        self
    }

    /// Sanity checks the runner relies on.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_devices() >= 2, "scenarios need at least 2 devices");
        anyhow::ensure!(self.capacities[0] == 1.0, "central capacity must be 1.0");
        anyhow::ensure!(self.batches > 0 && self.inflight > 0, "empty training run");
        for e in &self.events {
            let dev = match &e.action {
                Action::Kill { device, .. } => *device,
                Action::SetCapacity { device, .. } => *device,
            };
            anyhow::ensure!(
                dev != 0 && dev < self.n_devices(),
                "script actions must target a worker (got device {dev})"
            );
        }
        Ok(())
    }
}
