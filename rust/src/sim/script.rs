//! Declarative failure-scenario scripts.
//!
//! A [`Scenario`] is everything the deterministic runner needs: cluster
//! shape, training hyper-parameters, the virtual network/compute model,
//! and a list of [`ScriptEvent`]s — "kill worker 2 when batch 40
//! completes", "slow worker 1 by 10x at t=2s", "kill another worker the
//! moment redistribution #1 starts". Triggers are expressed against
//! *protocol state* (batches completed, redistributions started) or
//! virtual time, never wall time, so a script means the same thing on
//! every machine.
//!
//! See DESIGN.md §7 for how to add a new scenario.

use std::time::Duration;

use crate::net::message::DeviceId;
use crate::net::quant::Compression;
use crate::util::rng::Rng;

/// When a scripted action fires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Trigger {
    /// When batch `b` completes at the central node (before the next
    /// injection — the pipeline quiesces at this batch when inflight=1).
    BatchDone(u64),
    /// At an absolute virtual time.
    At(Duration),
    /// The moment the `n`-th redistribution (1-based) begins — i.e. the
    /// `Repartition` broadcast and `FetchWeights` requests are already
    /// in flight. This is the "failure during an in-flight
    /// redistribution" hook.
    RedistributionStart(usize),
}

/// What happens when a trigger fires.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Kill a worker (state wiped, traffic dropped both ways). With
    /// `revive_after`, the device comes back that much later with empty
    /// state — the paper's case-2 "restarts as soon as it failed".
    Kill { device: DeviceId, revive_after: Option<Duration> },
    /// Change a device's capacity factor (e.g. 10.0 = now 10x slower) —
    /// drives the dynamic re-partition path.
    SetCapacity { device: DeviceId, capacity: f64 },
    /// Degrade (or restore) the virtual network's link bandwidth to
    /// `bps` bytes/sec from this moment on — the link-degradation hook
    /// of the `bandwidth` scenario family. In-flight transfers keep the
    /// rate they departed with; only subsequent sends are repriced.
    SetBandwidth { bps: f64 },
}

#[derive(Debug, Clone)]
pub struct ScriptEvent {
    pub at: Trigger,
    pub action: Action,
}

/// A complete scenario: deterministic given these fields + the fixture.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    /// Capacity factor per device; index 0 is the central node (1.0).
    pub capacities: Vec<f64>,
    /// Total training batches to complete.
    pub batches: u64,
    pub seed: u64,

    // --- training hyper-parameters ---
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Max in-flight batches (1 = fully serialized, quiesces between
    /// batches — the setting under which recovery is *exact*).
    pub inflight: usize,
    /// Weight aggregation interval factor (0 disables).
    pub agg_k: u32,
    /// Chain/global replication periods in batches (0 disables).
    pub chain_every: u64,
    pub global_every: u64,

    // --- schedules ---
    /// Dynamic re-partition: (first at batch, then every) — None disables.
    pub repartition: Option<(u64, u64)>,
    /// Central-node gradient timeout (virtual).
    pub fault_timeout: Duration,
    /// How long the coordinator waits for probe acks (virtual).
    pub probe_window: Duration,
    /// How long a redistribution may stall before re-probing (virtual) —
    /// this is what makes a mid-redistribution failure recoverable.
    pub redist_window: Duration,

    // --- virtual network + compute model ---
    pub bandwidth_bps: f64,
    pub latency: Duration,
    /// Modeled compute cost; per-batch stage time = flops × this × C_i.
    pub ns_per_flop: f64,

    /// Wire-compression policy for the whole cluster. `Off` keeps every
    /// tensor f32 with the pre-compression `byte_len` accounting and
    /// numerics, so all pre-compression scenario traces are unchanged.
    pub compression: Compression,

    pub events: Vec<ScriptEvent>,
}

impl Scenario {
    /// A conservative base: 3 devices, serialized pipeline, replicate
    /// every batch, momentum off — the configuration under which
    /// recovery is mathematically exact (see `rust/tests/scenarios/`).
    pub fn exact_recovery(name: &str, n_devices: usize, batches: u64) -> Scenario {
        Scenario {
            name: name.to_string(),
            capacities: vec![1.0; n_devices],
            batches,
            seed: 7,
            lr: 0.05,
            momentum: 0.0,
            weight_decay: 0.0,
            inflight: 1,
            agg_k: 0,
            chain_every: 1,
            global_every: 1,
            repartition: None,
            fault_timeout: Duration::from_millis(200),
            probe_window: Duration::from_millis(50),
            redist_window: Duration::from_secs(2),
            bandwidth_bps: 1e8,
            latency: Duration::from_micros(100),
            ns_per_flop: 1.0,
            compression: Compression::Off,
            events: vec![],
        }
    }

    /// A pipelined base (inflight = n_stages, momentum on, aggregation
    /// on): realistic async-1F1B behavior; recovery is asserted for
    /// continuity + determinism rather than exact weight equality.
    pub fn pipelined(name: &str, n_devices: usize, batches: u64) -> Scenario {
        Scenario {
            momentum: 0.9,
            weight_decay: 4e-5,
            inflight: n_devices,
            agg_k: 4,
            chain_every: 5,
            global_every: 10,
            ..Scenario::exact_recovery(name, n_devices, batches)
        }
    }

    pub fn n_devices(&self) -> usize {
        self.capacities.len()
    }

    pub fn with_events(mut self, events: Vec<ScriptEvent>) -> Scenario {
        self.events = events;
        self
    }

    pub fn with_compression(mut self, compression: Compression) -> Scenario {
        self.compression = compression;
        self
    }

    /// Sanity checks the runner relies on.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_devices() >= 2, "scenarios need at least 2 devices");
        anyhow::ensure!(self.capacities[0] == 1.0, "central capacity must be 1.0");
        anyhow::ensure!(self.batches > 0 && self.inflight > 0, "empty training run");
        for e in &self.events {
            let dev = match &e.action {
                Action::Kill { device, .. } => *device,
                Action::SetCapacity { device, .. } => *device,
                Action::SetBandwidth { bps } => {
                    anyhow::ensure!(
                        bps.is_finite() && *bps > 0.0,
                        "SetBandwidth needs a positive finite rate (got {bps})"
                    );
                    continue;
                }
            };
            anyhow::ensure!(
                dev != 0 && dev < self.n_devices(),
                "script actions must target a worker (got device {dev})"
            );
        }
        Ok(())
    }
}

/// Seeded chaos-schedule generator (ROADMAP: randomized-but-seeded
/// kill/slowdown coverage). Produces `n_events` scripted events at
/// strictly increasing, well-spaced batch marks:
///
/// * the first event is always a kill, so every chaos run exercises the
///   fault handler at least once;
/// * every kill revives within 10–60 virtual ms — far inside the default
///   200 ms gradient timeout, so the probe round finds the worker
///   alive-but-fresh (paper case 2) and the worker list never shrinks,
///   which keeps any generated schedule recoverable by construction;
/// * slowdowns draw a capacity factor in [1.5, 6.5].
///
/// The schedule is a pure function of `(n_devices, batches, n_events,
/// seed)`: two runs of one chaos scenario replay the identical timeline,
/// and the scenario suite asserts their traces are byte-identical.
pub fn chaos_events(
    n_devices: usize,
    batches: u64,
    n_events: usize,
    seed: u64,
) -> Vec<ScriptEvent> {
    assert!(n_devices >= 2, "chaos needs at least one worker");
    let mut rng = Rng::new(seed ^ 0xC4A0_5EED);
    let mut events = Vec::with_capacity(n_events);
    // leave headroom at both ends so every fault has batches left to
    // replay and the run can still quiesce
    let mut batch = 4 + rng.below(4);
    for i in 0..n_events {
        if batch + 5 >= batches {
            break;
        }
        let device = 1 + rng.below((n_devices - 1) as u64) as usize;
        let action = if i == 0 || rng.below(3) < 2 {
            Action::Kill {
                device,
                revive_after: Some(Duration::from_millis(10 + rng.below(51))),
            }
        } else {
            Action::SetCapacity { device, capacity: 1.5 + rng.next_f64() * 5.0 }
        };
        events.push(ScriptEvent { at: Trigger::BatchDone(batch), action });
        batch += 6 + rng.below(8);
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_schedule_is_seed_deterministic() {
        let a = chaos_events(4, 60, 5, 7);
        let b = chaos_events(4, 60, 5, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.action, y.action);
        }
        let c = chaos_events(4, 60, 5, 8);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.at != y.at || x.action != y.action),
            "different seeds should produce different schedules"
        );
    }

    #[test]
    fn chaos_schedule_is_recoverable_by_construction() {
        for seed in 0..32u64 {
            let evs = chaos_events(4, 80, 6, seed);
            assert!(!evs.is_empty());
            assert!(
                matches!(evs[0].action, Action::Kill { .. }),
                "seed {seed}: first event must be a kill"
            );
            let mut last = 0u64;
            for e in &evs {
                let Trigger::BatchDone(b) = e.at else {
                    panic!("chaos triggers are batch-based")
                };
                assert!(b > last || last == 0, "marks strictly increase");
                assert!(b + 5 < 80, "headroom at the end of the run");
                last = b;
                match &e.action {
                    Action::Kill { device, revive_after } => {
                        assert!((1..4).contains(device));
                        let r = revive_after.expect("chaos kills always revive");
                        assert!(r <= Duration::from_millis(60), "inside the fault timeout");
                    }
                    Action::SetCapacity { device, capacity } => {
                        assert!((1..4).contains(device));
                        assert!((1.5..=6.5).contains(capacity));
                    }
                    Action::SetBandwidth { .. } => panic!("chaos does not touch links"),
                }
            }
            // every generated schedule passes scenario validation
            Scenario::exact_recovery("chaos-gen", 4, 80).with_events(evs).validate().unwrap();
        }
    }
}
