//! Declarative failure-scenario scripts.
//!
//! A [`Scenario`] is everything the deterministic runner needs: cluster
//! shape, training hyper-parameters, the virtual network/compute model,
//! and a list of [`ScriptEvent`]s — "kill worker 2 when batch 40
//! completes", "slow worker 1 by 10x at t=2s", "kill another worker the
//! moment redistribution #1 starts". Triggers are expressed against
//! *protocol state* (batches completed, redistributions started) or
//! virtual time, never wall time, so a script means the same thing on
//! every machine.
//!
//! See DESIGN.md §7 for how to add a new scenario.

use std::time::Duration;

use crate::net::message::DeviceId;
use crate::net::quant::{AdaptiveThresholds, Compression};
use crate::util::rng::Rng;

/// When a scripted action fires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Trigger {
    /// When batch `b` completes at the central node (before the next
    /// injection — the pipeline quiesces at this batch when inflight=1).
    BatchDone(u64),
    /// At an absolute virtual time.
    At(Duration),
    /// The moment the `n`-th redistribution (1-based) begins — i.e. the
    /// `Repartition` broadcast and `FetchWeights` requests are already
    /// in flight. This is the "failure during an in-flight
    /// redistribution" hook.
    RedistributionStart(usize),
    /// The moment cross-replica sync round `r` (1-based) opens — every
    /// live chain has reached its round target and the barrier is about
    /// to fire. Only meaningful with `replicas > 1`; the replica runner
    /// applies these actions *before* stepping the phase machine, so a
    /// replica killed at its own sync round never contributes partials.
    SyncRound(u64),
}

/// What happens when a trigger fires.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Kill a worker (state wiped, traffic dropped both ways). With
    /// `revive_after`, the device comes back that much later with empty
    /// state — the paper's case-2 "restarts as soon as it failed".
    Kill { device: DeviceId, revive_after: Option<Duration> },
    /// Kill a contiguous slice of workers `first..=last` in one trigger —
    /// the correlated-failure form (a rack or region dying together).
    /// With `revive_after`, every device of the slice revives that much
    /// later with empty state; without it the slice is gone for good and
    /// recovery is a single case-3 re-partition over the survivors.
    KillSlice { first: DeviceId, last: DeviceId, revive_after: Option<Duration> },
    /// Change a device's capacity factor (e.g. 10.0 = now 10x slower) —
    /// drives the dynamic re-partition path.
    SetCapacity { device: DeviceId, capacity: f64 },
    /// Degrade (or restore) the virtual network's link bandwidth to
    /// `bps` bytes/sec from this moment on — the link-degradation hook
    /// of the `bandwidth` scenario family. In-flight transfers keep the
    /// rate they departed with; only subsequent sends are repriced.
    SetBandwidth { bps: f64 },
    /// Retarget one directed link `from -> to` to `bps` bytes/sec,
    /// overriding both the scalar default and any [`Scenario::link_bw`]
    /// entry for that link. In-flight transfers keep the rate they
    /// departed with, like [`Action::SetBandwidth`].
    SetLinkBandwidth { from: DeviceId, to: DeviceId, bps: f64 },
    /// Kill the central node (paper §III-E): all coordinator memory is
    /// lost — stage-0 weights, replica store, capacity estimates, batch
    /// pointers — and traffic to/from device 0 (including bytes already
    /// in flight on its links) is dropped. With `restart_after`, a
    /// [`Action::RestartCentral`] fires that much later; without it the
    /// script must contain an explicit `RestartCentral` event or the run
    /// can never finish (enforced by [`Scenario::validate`]).
    KillCentral { restart_after: Option<Duration> },
    /// Reboot the central node from the newest checkpoint in the
    /// harness's in-memory [`crate::checkpoint::MemorySink`] (or from
    /// the model's initial weights if nothing was ever checkpointed) and
    /// run the restart handshake against the surviving workers. Only
    /// meaningful on an [`Trigger::At`] trigger or via
    /// `KillCentral::restart_after` — batch/redistribution triggers
    /// cannot fire while the central node is down.
    RestartCentral,
    /// Kill an entire pipeline replica chain (hybrid parallelism,
    /// DESIGN.md §14): every device of chain `replica` dies for good and
    /// the survivors absorb its remaining data shard round-robin at the
    /// sync round the kill fires on. Chain 0 hosts the central node and
    /// cannot be killed. Requires `replicas > 1` and a
    /// [`Trigger::SyncRound`] trigger (enforced by
    /// [`Scenario::validate`]).
    KillReplica { replica: usize },
}

#[derive(Debug, Clone)]
pub struct ScriptEvent {
    pub at: Trigger,
    pub action: Action,
}

/// A complete scenario: deterministic given these fields + the fixture.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    /// Capacity factor per device; index 0 is the central node (1.0).
    pub capacities: Vec<f64>,
    /// Total training batches to complete.
    pub batches: u64,
    pub seed: u64,

    // --- training hyper-parameters ---
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Max in-flight batches (1 = fully serialized, quiesces between
    /// batches — the setting under which recovery is *exact*).
    pub inflight: usize,
    /// Weight aggregation interval factor (0 disables).
    pub agg_k: u32,
    /// Chain/global replication periods in batches (0 disables).
    pub chain_every: u64,
    pub global_every: u64,

    // --- schedules ---
    /// Dynamic re-partition: (first at batch, then every) — None disables.
    pub repartition: Option<(u64, u64)>,
    /// Central-node gradient timeout (virtual).
    pub fault_timeout: Duration,
    /// How long the coordinator waits for probe acks (virtual).
    pub probe_window: Duration,
    /// How long a redistribution may stall before re-probing (virtual) —
    /// this is what makes a mid-redistribution failure recoverable.
    pub redist_window: Duration,

    // --- virtual network + compute model ---
    pub bandwidth_bps: f64,
    /// Per-directed-link bandwidth overrides `(from, to, bps)` — the
    /// asymmetric wide-fleet topology form (see [`hetero_link_topology`]).
    /// Links without an entry fall back to the scalar `bandwidth_bps`;
    /// the empty default is exactly the old single-scalar fabric.
    pub link_bw: Vec<(DeviceId, DeviceId, f64)>,
    pub latency: Duration,
    /// Modeled compute cost; per-batch stage time = flops × this × C_i.
    pub ns_per_flop: f64,

    /// Wire-compression policy for the whole cluster. `Off` keeps every
    /// tensor f32 with the pre-compression `byte_len` accounting and
    /// numerics, so all pre-compression scenario traces are unchanged.
    /// `Adaptive` starts at tier off and walks the ladder per measured
    /// bandwidth ([`Scenario::adaptive`] thresholds, DESIGN.md §10).
    pub compression: Compression,
    /// Tier thresholds for `Compression::Adaptive` (ignored otherwise).
    pub adaptive: AdaptiveThresholds,
    /// Periodic link re-measurement cadence in batches (0 = only the
    /// one-shot init probe — the default, so existing traces are
    /// byte-identical). The adaptive policy needs this to observe
    /// scripted `SetBandwidth` degradation.
    pub bw_probe_every: u64,
    /// Fixed payload of those probes; 0 (default) auto-sizes from the
    /// last measurement (see `pipeline::stage::BW_PROBE_TARGET_S`).
    pub bw_probe_bytes: u64,

    /// Central-node checkpoint period in committed batches (paper
    /// §III-E), written to the harness's in-memory sink. 0 disables
    /// checkpointing entirely — the default, so every scenario that
    /// predates central-restart runs byte-identically.
    pub checkpoint_every: u64,

    /// Pipeline replica chains (hybrid parallelism, DESIGN.md §14). 1 —
    /// the default — is today's single-chain world and keeps every
    /// pre-existing trace byte-identical; R > 1 splits the fleet into R
    /// balanced chains fed disjoint round-robin batch shards, averaged
    /// every [`Scenario::sync_every`] committed batches per chain.
    pub replicas: usize,
    /// Cross-replica weight-sync period in per-chain committed batches.
    /// Required >= 1 when `replicas > 1`; ignored (0) otherwise.
    pub sync_every: u64,

    pub events: Vec<ScriptEvent>,
}

impl Scenario {
    /// A conservative base: 3 devices, serialized pipeline, replicate
    /// every batch, momentum off — the configuration under which
    /// recovery is mathematically exact (see `rust/tests/scenarios/`).
    pub fn exact_recovery(name: &str, n_devices: usize, batches: u64) -> Scenario {
        Scenario {
            name: name.to_string(),
            capacities: vec![1.0; n_devices],
            batches,
            seed: 7,
            lr: 0.05,
            momentum: 0.0,
            weight_decay: 0.0,
            inflight: 1,
            agg_k: 0,
            chain_every: 1,
            global_every: 1,
            repartition: None,
            fault_timeout: Duration::from_millis(200),
            probe_window: Duration::from_millis(50),
            redist_window: Duration::from_secs(2),
            bandwidth_bps: 1e8,
            link_bw: vec![],
            latency: Duration::from_micros(100),
            ns_per_flop: 1.0,
            compression: Compression::Off,
            adaptive: AdaptiveThresholds::default(),
            bw_probe_every: 0,
            bw_probe_bytes: 0,
            checkpoint_every: 0,
            replicas: 1,
            sync_every: 0,
            events: vec![],
        }
    }

    /// A pipelined base (inflight = n_stages, momentum on, aggregation
    /// on): realistic async-1F1B behavior; recovery is asserted for
    /// continuity + determinism rather than exact weight equality.
    pub fn pipelined(name: &str, n_devices: usize, batches: u64) -> Scenario {
        Scenario {
            momentum: 0.9,
            weight_decay: 4e-5,
            inflight: n_devices,
            agg_k: 4,
            chain_every: 5,
            global_every: 10,
            ..Scenario::exact_recovery(name, n_devices, batches)
        }
    }

    pub fn n_devices(&self) -> usize {
        self.capacities.len()
    }

    pub fn with_events(mut self, events: Vec<ScriptEvent>) -> Scenario {
        self.events = events;
        self
    }

    /// Install a per-directed-link bandwidth topology (see
    /// [`hetero_link_topology`]).
    pub fn with_link_bw(mut self, link_bw: Vec<(DeviceId, DeviceId, f64)>) -> Scenario {
        self.link_bw = link_bw;
        self
    }

    /// The scripted bandwidth of the directed link `from -> to`: the
    /// per-link override if one exists, else the scalar default. This is
    /// the *initial* topology — runtime [`Action::SetBandwidth`] /
    /// [`Action::SetLinkBandwidth`] retargets are visible only to the
    /// virtual fabric, not this accessor, so cost-model fallbacks keep
    /// their pre-override pricing (see `Runner::cost_model`).
    pub fn link_bw_for(&self, from: DeviceId, to: DeviceId) -> f64 {
        self.link_bw
            .iter()
            .find(|&&(f, t, _)| f == from && t == to)
            .map(|&(_, _, b)| b)
            .unwrap_or(self.bandwidth_bps)
    }

    pub fn with_compression(mut self, compression: Compression) -> Scenario {
        self.compression = compression;
        self
    }

    /// Set the adaptive-tier thresholds (implies nothing unless
    /// `compression == Adaptive`).
    pub fn with_adaptive(mut self, thresholds: AdaptiveThresholds) -> Scenario {
        self.adaptive = thresholds;
        self
    }

    /// Re-measure link bandwidth every `every` batches (0 = off).
    pub fn with_bw_probe_every(mut self, every: u64) -> Scenario {
        self.bw_probe_every = every;
        self
    }

    /// Checkpoint every `every` committed batches (0 = off).
    pub fn with_checkpoint(mut self, every: u64) -> Scenario {
        self.checkpoint_every = every;
        self
    }

    /// Split the fleet into `replicas` pipeline chains synchronized
    /// every `sync_every` per-chain committed batches (DESIGN.md §14).
    pub fn with_replicas(mut self, replicas: usize, sync_every: u64) -> Scenario {
        self.replicas = replicas;
        self.sync_every = sync_every;
        self
    }

    /// Sanity checks the runner relies on.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_devices() >= 2, "scenarios need at least 2 devices");
        anyhow::ensure!(self.capacities[0] == 1.0, "central capacity must be 1.0");
        anyhow::ensure!(self.batches > 0 && self.inflight > 0, "empty training run");
        anyhow::ensure!(self.replicas >= 1, "replicas must be >= 1");
        if self.replicas > 1 {
            // The replica runner models each chain as a fused stage and
            // drives only the sync/kill machinery; every single-chain
            // subsystem below is out of scope for R > 1 and must be off
            // so a script cannot silently expect behavior that never
            // runs (DESIGN.md §14).
            anyhow::ensure!(
                self.sync_every >= 1,
                "replicas > 1 needs sync_every >= 1 (the sync barrier is the commit point)"
            );
            anyhow::ensure!(
                self.n_devices() >= self.replicas,
                "need at least one device per replica chain (got {} devices, {} replicas)",
                self.n_devices(),
                self.replicas
            );
            anyhow::ensure!(
                self.compression != Compression::Adaptive,
                "replicas > 1 does not support adaptive compression (fixed tiers only)"
            );
            anyhow::ensure!(
                self.repartition.is_none()
                    && self.checkpoint_every == 0
                    && self.bw_probe_every == 0
                    && self.agg_k == 0
                    && self.chain_every == 0
                    && self.global_every == 0,
                "replicas > 1 is incompatible with dynamic repartition, checkpointing, \
                 bandwidth probing, aggregation, and chain/global replication"
            );
            for e in &self.events {
                anyhow::ensure!(
                    matches!(e.action, Action::KillReplica { .. })
                        && matches!(e.at, Trigger::SyncRound(_)),
                    "replicas > 1 scripts may only use SyncRound-triggered KillReplica \
                     events (got {:?} at {:?})",
                    e.action,
                    e.at
                );
            }
        } else {
            for e in &self.events {
                anyhow::ensure!(
                    !matches!(e.at, Trigger::SyncRound(_)),
                    "SyncRound triggers need replicas > 1 (single-chain runs have no \
                     sync rounds)"
                );
            }
        }
        if self.compression == Compression::Adaptive {
            self.adaptive.validate()?;
        }
        for &(from, to, bps) in &self.link_bw {
            anyhow::ensure!(
                bps.is_finite() && bps > 0.0,
                "link_bw needs positive finite rates (got {from}->{to} @ {bps})"
            );
            anyhow::ensure!(
                from != to && from < self.n_devices() && to < self.n_devices(),
                "link_bw entries must connect distinct in-range devices (got {from}->{to})"
            );
        }
        let mut unrescued_central_kill = false;
        let mut has_at_restart = false;
        for e in &self.events {
            let dev = match &e.action {
                Action::Kill { device, .. } => *device,
                Action::SetCapacity { device, .. } => *device,
                Action::KillSlice { first, last, .. } => {
                    anyhow::ensure!(
                        *first >= 1 && first <= last && *last < self.n_devices(),
                        "KillSlice needs 1 <= first <= last < n_devices \
                         (got {first}..={last} with {} devices)",
                        self.n_devices()
                    );
                    continue;
                }
                Action::SetLinkBandwidth { from, to, bps } => {
                    anyhow::ensure!(
                        bps.is_finite() && *bps > 0.0,
                        "SetLinkBandwidth needs a positive finite rate (got {bps})"
                    );
                    anyhow::ensure!(
                        from != to && *from < self.n_devices() && *to < self.n_devices(),
                        "SetLinkBandwidth needs a directed link between distinct in-range \
                         devices (got {from}->{to})"
                    );
                    continue;
                }
                Action::SetBandwidth { bps } => {
                    anyhow::ensure!(
                        bps.is_finite() && *bps > 0.0,
                        "SetBandwidth needs a positive finite rate (got {bps})"
                    );
                    continue;
                }
                Action::KillCentral { restart_after } => {
                    if restart_after.is_none() {
                        unrescued_central_kill = true;
                    }
                    continue;
                }
                Action::RestartCentral => {
                    // only an At trigger can fire while the central node
                    // is down: batches don't complete and redistributions
                    // don't start without a coordinator, so a batch- or
                    // redist-triggered restart can never rescue a kill
                    anyhow::ensure!(
                        matches!(e.at, Trigger::At(_)),
                        "RestartCentral must use an At(..) trigger (got {:?}): batch and \
                         redistribution triggers cannot fire while the central node is down",
                        e.at
                    );
                    has_at_restart = true;
                    continue;
                }
                Action::KillReplica { replica } => {
                    anyhow::ensure!(
                        self.replicas > 1,
                        "KillReplica needs replicas > 1 (got replicas = {})",
                        self.replicas
                    );
                    anyhow::ensure!(
                        *replica >= 1 && *replica < self.replicas,
                        "KillReplica must target a non-central chain 1..{} (got {replica})",
                        self.replicas
                    );
                    anyhow::ensure!(
                        matches!(e.at, Trigger::SyncRound(r) if r >= 1),
                        "KillReplica must use a SyncRound(r >= 1) trigger (got {:?})",
                        e.at
                    );
                    continue;
                }
            };
            anyhow::ensure!(
                dev != 0 && dev < self.n_devices(),
                "script actions must target a worker (got device {dev})"
            );
        }
        anyhow::ensure!(
            !unrescued_central_kill || has_at_restart,
            "KillCentral without restart_after needs an At(..)-triggered RestartCentral \
             event (a dead coordinator can never finish the run); note an At time before \
             the kill still deadlocks — prefer KillCentral{{restart_after}}"
        );
        Ok(())
    }
}

/// Seeded chaos-schedule generator (ROADMAP: randomized-but-seeded
/// kill/slowdown coverage). Produces `n_events` scripted events at
/// strictly increasing, well-spaced batch marks:
///
/// * the first event is always a kill, so every chaos run exercises the
///   fault handler at least once;
/// * every kill revives within 10–60 virtual ms — far inside the default
///   200 ms gradient timeout, so the probe round finds the worker
///   alive-but-fresh (paper case 2) and the worker list never shrinks,
///   which keeps any generated schedule recoverable by construction;
/// * slowdowns draw a capacity factor in [1.5, 6.5].
///
/// The schedule is a pure function of `(n_devices, batches, n_events,
/// seed)`: two runs of one chaos scenario replay the identical timeline,
/// and the scenario suite asserts their traces are byte-identical.
pub fn chaos_events(
    n_devices: usize,
    batches: u64,
    n_events: usize,
    seed: u64,
) -> Vec<ScriptEvent> {
    assert!(n_devices >= 2, "chaos needs at least one worker");
    let mut rng = Rng::new(seed ^ 0xC4A0_5EED);
    let mut events = Vec::with_capacity(n_events);
    // leave headroom at both ends so every fault has batches left to
    // replay and the run can still quiesce
    let mut batch = 4 + rng.below(4);
    for i in 0..n_events {
        if batch + 5 >= batches {
            break;
        }
        let device = 1 + rng.below((n_devices - 1) as u64) as usize;
        let action = if i == 0 || rng.below(3) < 2 {
            Action::Kill {
                device,
                revive_after: Some(Duration::from_millis(10 + rng.below(51))),
            }
        } else {
            Action::SetCapacity { device, capacity: 1.5 + rng.next_f64() * 5.0 }
        };
        events.push(ScriptEvent { at: Trigger::BatchDone(batch), action });
        batch += 6 + rng.below(8);
    }
    events
}

/// Rolling-wave churn generator (continuous join/leave across a wide
/// fleet): `waves` waves, each killing `per_wave` distinct workers
/// round-robin across the pool at one batch mark, every kill reviving
/// within 10–60 virtual ms — inside any sane fault timeout, so each wave
/// is observed as case-2 restarts and the worker list never shrinks,
/// which keeps any generated schedule recoverable by construction. Wave
/// marks are 3–5 batches apart; generation stops early if the run would
/// lose its quiesce headroom. A pure function of the arguments, like
/// [`chaos_events`].
pub fn rolling_churn_events(
    n_devices: usize,
    batches: u64,
    waves: usize,
    per_wave: usize,
    seed: u64,
) -> Vec<ScriptEvent> {
    assert!(n_devices >= 2, "churn needs at least one worker");
    assert!(per_wave >= 1 && per_wave < n_devices, "per_wave must fit the worker pool");
    let mut rng = Rng::new(seed ^ 0x0C11_B01D);
    let mut events = Vec::with_capacity(waves * per_wave);
    let mut mark = 4 + rng.below(3);
    let mut cursor = 1usize; // round-robin over workers, skipping the central node
    for _ in 0..waves {
        if mark + 3 >= batches {
            break;
        }
        for _ in 0..per_wave {
            let device = cursor;
            cursor += 1;
            if cursor >= n_devices {
                cursor = 1;
            }
            events.push(ScriptEvent {
                at: Trigger::BatchDone(mark),
                action: Action::Kill {
                    device,
                    revive_after: Some(Duration::from_millis(10 + rng.below(51))),
                },
            });
        }
        mark += 3 + rng.below(3);
    }
    events
}

/// p99.9 straggler generator: `n_spikes` spikes, each slowing one worker
/// by a 20–60x capacity factor at a batch mark and restoring it to its
/// scripted capacity 2–4 batches later. Models tail latency — a device
/// pausing for GC or thermal throttling — rather than failure: nothing
/// dies, so a scenario using this must keep `fault_timeout` above the
/// spiked stage time or the detector will (correctly) call it a fault.
/// A pure function of `(capacities, batches, n_spikes, seed)`.
pub fn straggler_events(
    capacities: &[f64],
    batches: u64,
    n_spikes: usize,
    seed: u64,
) -> Vec<ScriptEvent> {
    let n_devices = capacities.len();
    assert!(n_devices >= 2, "stragglers need at least one worker");
    let mut rng = Rng::new(seed ^ 0x57A6_61E5);
    let mut events = Vec::with_capacity(n_spikes * 2);
    let mut mark = 4 + rng.below(3);
    for _ in 0..n_spikes {
        if mark + 6 >= batches {
            break;
        }
        let device = 1 + rng.below((n_devices - 1) as u64) as usize;
        let spike = 20.0 + rng.next_f64() * 40.0;
        events.push(ScriptEvent {
            at: Trigger::BatchDone(mark),
            action: Action::SetCapacity { device, capacity: capacities[device] * spike },
        });
        let restore = mark + 2 + rng.below(3);
        events.push(ScriptEvent {
            at: Trigger::BatchDone(restore),
            action: Action::SetCapacity { device, capacity: capacities[device] },
        });
        mark = restore + 2 + rng.below(3);
    }
    events
}

/// Directed heterogeneous link topology for a linear pipeline over
/// devices `0..n`: both directions of every pipeline hop `(d, d+1)`,
/// plus the replication links `(d, 0)` / `(0, d)` for `d >= 2`, each
/// drawn uniformly from `[lo_bps, hi_bps]`. Asymmetric by construction —
/// the two directions of a hop draw independently, like real
/// uplink/downlink asymmetry. A pure function of the arguments; feed the
/// result to [`Scenario::with_link_bw`].
pub fn hetero_link_topology(
    n_devices: usize,
    lo_bps: f64,
    hi_bps: f64,
    seed: u64,
) -> Vec<(DeviceId, DeviceId, f64)> {
    assert!(n_devices >= 2, "a topology needs at least one link");
    assert!(lo_bps > 0.0 && hi_bps >= lo_bps, "need 0 < lo_bps <= hi_bps");
    let mut rng = Rng::new(seed ^ 0x7090_A011);
    let mut links = Vec::with_capacity(4 * n_devices);
    for d in 0..n_devices - 1 {
        links.push((d, d + 1, lo_bps + rng.next_f64() * (hi_bps - lo_bps)));
        links.push((d + 1, d, lo_bps + rng.next_f64() * (hi_bps - lo_bps)));
    }
    for d in 2..n_devices {
        links.push((d, 0, lo_bps + rng.next_f64() * (hi_bps - lo_bps)));
        links.push((0, d, lo_bps + rng.next_f64() * (hi_bps - lo_bps)));
    }
    links
}

/// Heterogeneous capacity vector: central node at 1.0 (a runner
/// invariant), workers drawn uniformly from `[1.0, max_factor]` — the
/// paper's "10x heterogeneity" is `max_factor = 10.0`. A pure function
/// of the arguments.
pub fn hetero_capacities(n_devices: usize, max_factor: f64, seed: u64) -> Vec<f64> {
    assert!(n_devices >= 2, "a cluster needs at least one worker");
    assert!(max_factor >= 1.0, "capacity factors are >= 1.0 (1.0 = fastest)");
    let mut rng = Rng::new(seed ^ 0xCA9A_C171);
    let mut caps = Vec::with_capacity(n_devices);
    caps.push(1.0);
    for _ in 1..n_devices {
        caps.push(1.0 + rng.next_f64() * (max_factor - 1.0));
    }
    caps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_schedule_is_seed_deterministic() {
        let a = chaos_events(4, 60, 5, 7);
        let b = chaos_events(4, 60, 5, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.action, y.action);
        }
        let c = chaos_events(4, 60, 5, 8);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.at != y.at || x.action != y.action),
            "different seeds should produce different schedules"
        );
    }

    #[test]
    fn chaos_schedule_is_recoverable_by_construction() {
        for seed in 0..32u64 {
            let evs = chaos_events(4, 80, 6, seed);
            assert!(!evs.is_empty());
            assert!(
                matches!(evs[0].action, Action::Kill { .. }),
                "seed {seed}: first event must be a kill"
            );
            let mut last = 0u64;
            for e in &evs {
                let Trigger::BatchDone(b) = e.at else {
                    panic!("chaos triggers are batch-based")
                };
                assert!(b > last || last == 0, "marks strictly increase");
                assert!(b + 5 < 80, "headroom at the end of the run");
                last = b;
                match &e.action {
                    Action::Kill { device, revive_after } => {
                        assert!((1..4).contains(device));
                        let r = revive_after.expect("chaos kills always revive");
                        assert!(r <= Duration::from_millis(60), "inside the fault timeout");
                    }
                    Action::SetCapacity { device, capacity } => {
                        assert!((1..4).contains(device));
                        assert!((1.5..=6.5).contains(capacity));
                    }
                    other => panic!("chaos only kills and slows workers, got {other:?}"),
                }
            }
            // every generated schedule passes scenario validation
            Scenario::exact_recovery("chaos-gen", 4, 80).with_events(evs).validate().unwrap();
        }
    }

    #[test]
    fn validate_enforces_central_restart_rescue_rules() {
        let base = Scenario::exact_recovery("v", 3, 20);
        // an unrescued central kill can never finish the run
        let sc = base.clone().with_events(vec![ScriptEvent {
            at: Trigger::BatchDone(5),
            action: Action::KillCentral { restart_after: None },
        }]);
        assert!(sc.validate().is_err());
        // inline restart_after rescues
        let sc = base.clone().with_events(vec![ScriptEvent {
            at: Trigger::BatchDone(5),
            action: Action::KillCentral { restart_after: Some(Duration::from_millis(10)) },
        }]);
        sc.validate().unwrap();
        // an At-triggered RestartCentral rescues
        let sc = base.clone().with_events(vec![
            ScriptEvent {
                at: Trigger::BatchDone(5),
                action: Action::KillCentral { restart_after: None },
            },
            ScriptEvent {
                at: Trigger::At(Duration::from_secs(2)),
                action: Action::RestartCentral,
            },
        ]);
        sc.validate().unwrap();
        // a batch-triggered RestartCentral can never fire while the
        // central is down — reject it outright
        let sc = base.with_events(vec![
            ScriptEvent {
                at: Trigger::BatchDone(5),
                action: Action::KillCentral { restart_after: None },
            },
            ScriptEvent { at: Trigger::BatchDone(9), action: Action::RestartCentral },
        ]);
        assert!(sc.validate().is_err());
    }

    #[test]
    fn chaos_first_event_is_always_a_kill() {
        for n_devices in 2..=6usize {
            for seed in 0..16u64 {
                let evs = chaos_events(n_devices, 100, 5, seed);
                assert!(!evs.is_empty(), "n={n_devices} seed={seed}: empty schedule");
                match &evs[0].action {
                    Action::Kill { device, revive_after } => {
                        assert!(
                            (1..n_devices).contains(device),
                            "n={n_devices} seed={seed}: kill targets a worker"
                        );
                        assert!(revive_after.is_some());
                    }
                    other => panic!("n={n_devices} seed={seed}: first event {other:?} not a kill"),
                }
            }
        }
    }

    #[test]
    fn chaos_revives_land_inside_the_fault_timeout() {
        // the documented band is 10–60 ms — far inside the 200 ms
        // gradient timeout of the exact-recovery base, so a chaos kill is
        // always observed as a case-2 restart, never a lost worker
        let timeout = Scenario::exact_recovery("probe", 4, 10).fault_timeout;
        for seed in 0..64u64 {
            for e in chaos_events(4, 120, 8, seed) {
                if let Action::Kill { revive_after, .. } = &e.action {
                    let r = revive_after.expect("chaos kills always revive");
                    assert!(
                        r >= Duration::from_millis(10) && r <= Duration::from_millis(60),
                        "seed {seed}: revive {r:?} outside the documented 10-60ms band"
                    );
                    assert!(r < timeout, "seed {seed}: revive {r:?} past the {timeout:?} timeout");
                }
            }
        }
    }

    #[test]
    fn chaos_capacities_stay_inside_the_documented_band() {
        let mut seen_slowdown = false;
        for seed in 0..64u64 {
            for e in chaos_events(5, 120, 8, seed) {
                if let Action::SetCapacity { capacity, .. } = &e.action {
                    seen_slowdown = true;
                    assert!(
                        (1.5..=6.5).contains(capacity),
                        "seed {seed}: capacity {capacity} outside [1.5, 6.5]"
                    );
                }
            }
        }
        assert!(seen_slowdown, "64 seeds x 8 events never drew a slowdown");
    }

    #[test]
    fn rolling_churn_is_deterministic_and_case2_by_construction() {
        let a = rolling_churn_events(12, 40, 3, 3, 7);
        let b = rolling_churn_events(12, 40, 3, 3, 7);
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.action, y.action);
        }
        let mut devices_seen = std::collections::BTreeSet::new();
        for e in &a {
            let Action::Kill { device, revive_after } = &e.action else {
                panic!("churn only kills, got {:?}", e.action)
            };
            assert!((1..12).contains(device));
            devices_seen.insert(*device);
            let r = revive_after.expect("churn kills always revive");
            assert!(
                r >= Duration::from_millis(10) && r <= Duration::from_millis(60),
                "revive {r:?} outside the case-2 band"
            );
        }
        // 3 waves x 3 kills round-robin over 11 workers: no repeats yet
        assert_eq!(devices_seen.len(), a.len(), "round-robin must not repeat early");
        Scenario::exact_recovery("churn-gen", 12, 40)
            .with_events(a)
            .validate()
            .unwrap();
    }

    #[test]
    fn rolling_churn_waves_share_marks_and_respect_headroom() {
        for seed in 0..32u64 {
            let evs = rolling_churn_events(8, 30, 5, 2, seed);
            let mut prev: Option<u64> = None;
            for pair in evs.chunks(2) {
                let Trigger::BatchDone(m0) = pair[0].at else { panic!() };
                let Trigger::BatchDone(m1) = pair[1].at else { panic!() };
                assert_eq!(m0, m1, "seed {seed}: a wave fires at one mark");
                assert!(m0 >= 4 && m0 + 3 < 30, "seed {seed}: mark {m0} headroom");
                if let Some(p) = prev {
                    assert!(m0 > p && m0 - p >= 3, "seed {seed}: waves too close ({p}->{m0})");
                }
                prev = Some(m0);
            }
        }
    }

    #[test]
    fn straggler_spikes_pair_with_restores() {
        for seed in 0..32u64 {
            let caps = hetero_capacities(6, 4.0, seed);
            let evs = straggler_events(&caps, 40, 3, seed);
            assert!(evs.len() % 2 == 0, "seed {seed}: spikes pair with restores");
            assert!(!evs.is_empty());
            for pair in evs.chunks(2) {
                let (Action::SetCapacity { device: d0, capacity: spiked },
                     Action::SetCapacity { device: d1, capacity: restored }) =
                    (&pair[0].action, &pair[1].action)
                else {
                    panic!("seed {seed}: stragglers only set capacity")
                };
                assert_eq!(d0, d1, "seed {seed}: restore targets the spiked device");
                let base = caps[*d0];
                assert_eq!(*restored, base, "seed {seed}: restore returns to scripted cap");
                let factor = spiked / base;
                assert!(
                    (20.0..=60.0).contains(&factor),
                    "seed {seed}: spike factor {factor} outside [20, 60]"
                );
                let (Trigger::BatchDone(m0), Trigger::BatchDone(m1)) = (&pair[0].at, &pair[1].at)
                else {
                    panic!()
                };
                assert!(*m1 > *m0 && *m1 - *m0 <= 4, "seed {seed}: restore 2-4 batches later");
            }
            Scenario::exact_recovery("strag-gen", 6, 40)
                .with_events(evs)
                .validate()
                .unwrap();
        }
    }

    #[test]
    fn hetero_topology_covers_hops_and_replication_links() {
        let n = 16;
        let links = hetero_link_topology(n, 2e7, 2e8, 9);
        let again = hetero_link_topology(n, 2e7, 2e8, 9);
        assert_eq!(links, again, "topology is seed-deterministic");
        let keys: std::collections::BTreeSet<(usize, usize)> =
            links.iter().map(|&(f, t, _)| (f, t)).collect();
        assert_eq!(keys.len(), links.len(), "no duplicate directed links");
        for d in 0..n - 1 {
            assert!(keys.contains(&(d, d + 1)) && keys.contains(&(d + 1, d)), "hop {d} both ways");
        }
        for d in 2..n {
            assert!(keys.contains(&(d, 0)) && keys.contains(&(0, d)), "replication link {d}");
        }
        for &(_, _, bps) in &links {
            assert!((2e7..=2e8).contains(&bps), "bandwidth {bps} outside the band");
        }
        // the two directions of a hop are drawn independently: at least
        // one hop must come out asymmetric
        assert!(
            (0..n - 1).any(|d| {
                let up = links.iter().find(|&&(f, t, _)| (f, t) == (d, d + 1)).unwrap().2;
                let down = links.iter().find(|&&(f, t, _)| (f, t) == (d + 1, d)).unwrap().2;
                up != down
            }),
            "every hop symmetric — the generator is not asymmetric"
        );
        let mut sc = Scenario::exact_recovery("topo-gen", n, 10).with_link_bw(links);
        sc.validate().unwrap();
        // override beats the scalar default; unlisted links fall back
        sc.link_bw = vec![(0, 1, 5e6)];
        assert_eq!(sc.link_bw_for(0, 1), 5e6);
        assert_eq!(sc.link_bw_for(1, 0), sc.bandwidth_bps);
    }

    #[test]
    fn hetero_capacities_pin_the_central_node() {
        let caps = hetero_capacities(32, 10.0, 5);
        assert_eq!(caps, hetero_capacities(32, 10.0, 5));
        assert_eq!(caps[0], 1.0, "central capacity is a runner invariant");
        assert!(caps[1..].iter().all(|c| (1.0..=10.0).contains(c)));
        Scenario::exact_recovery("caps-gen", 32, 10)
            .with_events(vec![])
            .validate()
            .unwrap();
    }

    #[test]
    fn validate_checks_slices_and_links() {
        let base = Scenario::exact_recovery("v2", 6, 20);
        // KillSlice must stay inside the worker pool
        for (first, last, ok) in
            [(1, 3, true), (0, 2, false), (3, 2, false), (4, 6, false), (5, 5, true)]
        {
            let sc = base.clone().with_events(vec![ScriptEvent {
                at: Trigger::BatchDone(5),
                action: Action::KillSlice {
                    first,
                    last,
                    revive_after: Some(Duration::from_millis(20)),
                },
            }]);
            assert_eq!(sc.validate().is_ok(), ok, "KillSlice {first}..={last}");
        }
        // SetLinkBandwidth needs a real directed link and a sane rate
        for (from, to, bps, ok) in
            [(0, 1, 1e7, true), (1, 1, 1e7, false), (0, 6, 1e7, false), (0, 1, -1.0, false)]
        {
            let sc = base.clone().with_events(vec![ScriptEvent {
                at: Trigger::At(Duration::from_millis(1)),
                action: Action::SetLinkBandwidth { from, to, bps },
            }]);
            assert_eq!(sc.validate().is_ok(), ok, "link {from}->{to} @ {bps}");
        }
        // static topology entries are validated the same way
        let mut sc = base.clone();
        sc.link_bw = vec![(2, 2, 1e7)];
        assert!(sc.validate().is_err(), "self-link in link_bw");
        sc.link_bw = vec![(0, 1, f64::NAN)];
        assert!(sc.validate().is_err(), "NaN rate in link_bw");
    }

    #[test]
    fn validate_enforces_replica_script_rules() {
        // the default is the single-chain world
        let base = Scenario::exact_recovery("rep", 6, 20);
        assert_eq!((base.replicas, base.sync_every), (1, 0));
        base.validate().unwrap();
        // R > 1 needs a sync period and the single-chain subsystems off
        assert!(base.clone().with_replicas(2, 0).validate().is_err(), "sync_every >= 1");
        assert!(base.clone().with_replicas(7, 5).validate().is_err(), "chains need devices");
        assert!(base.clone().with_replicas(0, 5).validate().is_err(), "replicas >= 1");
        let mut sc = base.clone().with_replicas(2, 5);
        sc.chain_every = 0;
        sc.global_every = 0;
        sc.validate().unwrap();
        let mut repl = sc.clone();
        repl.repartition = Some((5, 5));
        assert!(repl.validate().is_err(), "repartition is single-chain only");
        let mut ck = sc.clone();
        ck.checkpoint_every = 4;
        assert!(ck.validate().is_err(), "checkpointing is single-chain only");
        // chain/global replication defaults (1/1) are rejected for R > 1
        assert!(base.clone().with_replicas(2, 5).validate().is_err());
        // KillReplica: needs R > 1, a live non-central chain, a SyncRound trigger
        let kill = |at: Trigger, replica: usize| {
            vec![ScriptEvent { at, action: Action::KillReplica { replica } }]
        };
        sc.clone().with_events(kill(Trigger::SyncRound(1), 1)).validate().unwrap();
        assert!(
            sc.clone().with_events(kill(Trigger::SyncRound(1), 0)).validate().is_err(),
            "chain 0 hosts the central node"
        );
        assert!(
            sc.clone().with_events(kill(Trigger::SyncRound(1), 2)).validate().is_err(),
            "chain index out of range"
        );
        assert!(
            sc.clone().with_events(kill(Trigger::BatchDone(5), 1)).validate().is_err(),
            "KillReplica needs a SyncRound trigger"
        );
        assert!(
            base.clone().with_events(kill(Trigger::SyncRound(1), 1)).validate().is_err(),
            "KillReplica needs replicas > 1"
        );
        // non-replica actions are rejected inside an R > 1 script
        let mixed = sc.clone().with_events(vec![ScriptEvent {
            at: Trigger::BatchDone(5),
            action: Action::Kill { device: 1, revive_after: None },
        }]);
        assert!(mixed.validate().is_err(), "R > 1 scripts are KillReplica-only");
        // SyncRound triggers make no sense in a single-chain run
        let stray = base.clone().with_events(vec![ScriptEvent {
            at: Trigger::SyncRound(1),
            action: Action::SetBandwidth { bps: 1e7 },
        }]);
        assert!(stray.validate().is_err(), "SyncRound trigger needs replicas > 1");
    }

    #[test]
    fn chaos_marks_strictly_increase_with_headroom() {
        for seed in 0..64u64 {
            let batches = 90u64;
            let mut prev: Option<u64> = None;
            for e in chaos_events(4, batches, 10, seed) {
                let Trigger::BatchDone(b) = e.at else {
                    panic!("seed {seed}: chaos triggers are batch-based")
                };
                assert!(b >= 4, "seed {seed}: mark {b} leaves no warm-up headroom");
                assert!(b + 5 < batches, "seed {seed}: mark {b} leaves no quiesce headroom");
                if let Some(p) = prev {
                    assert!(b > p, "seed {seed}: marks must strictly increase ({p} -> {b})");
                    assert!(b - p >= 6, "seed {seed}: marks too close ({p} -> {b})");
                }
                prev = Some(b);
            }
        }
    }
}
