//! Declarative failure-scenario scripts.
//!
//! A [`Scenario`] is everything the deterministic runner needs: cluster
//! shape, training hyper-parameters, the virtual network/compute model,
//! and a list of [`ScriptEvent`]s — "kill worker 2 when batch 40
//! completes", "slow worker 1 by 10x at t=2s", "kill another worker the
//! moment redistribution #1 starts". Triggers are expressed against
//! *protocol state* (batches completed, redistributions started) or
//! virtual time, never wall time, so a script means the same thing on
//! every machine.
//!
//! See DESIGN.md §7 for how to add a new scenario.

use std::time::Duration;

use crate::net::message::DeviceId;
use crate::net::quant::{AdaptiveThresholds, Compression};
use crate::util::rng::Rng;

/// When a scripted action fires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Trigger {
    /// When batch `b` completes at the central node (before the next
    /// injection — the pipeline quiesces at this batch when inflight=1).
    BatchDone(u64),
    /// At an absolute virtual time.
    At(Duration),
    /// The moment the `n`-th redistribution (1-based) begins — i.e. the
    /// `Repartition` broadcast and `FetchWeights` requests are already
    /// in flight. This is the "failure during an in-flight
    /// redistribution" hook.
    RedistributionStart(usize),
}

/// What happens when a trigger fires.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Kill a worker (state wiped, traffic dropped both ways). With
    /// `revive_after`, the device comes back that much later with empty
    /// state — the paper's case-2 "restarts as soon as it failed".
    Kill { device: DeviceId, revive_after: Option<Duration> },
    /// Change a device's capacity factor (e.g. 10.0 = now 10x slower) —
    /// drives the dynamic re-partition path.
    SetCapacity { device: DeviceId, capacity: f64 },
    /// Degrade (or restore) the virtual network's link bandwidth to
    /// `bps` bytes/sec from this moment on — the link-degradation hook
    /// of the `bandwidth` scenario family. In-flight transfers keep the
    /// rate they departed with; only subsequent sends are repriced.
    SetBandwidth { bps: f64 },
    /// Kill the central node (paper §III-E): all coordinator memory is
    /// lost — stage-0 weights, replica store, capacity estimates, batch
    /// pointers — and traffic to/from device 0 (including bytes already
    /// in flight on its links) is dropped. With `restart_after`, a
    /// [`Action::RestartCentral`] fires that much later; without it the
    /// script must contain an explicit `RestartCentral` event or the run
    /// can never finish (enforced by [`Scenario::validate`]).
    KillCentral { restart_after: Option<Duration> },
    /// Reboot the central node from the newest checkpoint in the
    /// harness's in-memory [`crate::checkpoint::MemorySink`] (or from
    /// the model's initial weights if nothing was ever checkpointed) and
    /// run the restart handshake against the surviving workers. Only
    /// meaningful on an [`Trigger::At`] trigger or via
    /// `KillCentral::restart_after` — batch/redistribution triggers
    /// cannot fire while the central node is down.
    RestartCentral,
}

#[derive(Debug, Clone)]
pub struct ScriptEvent {
    pub at: Trigger,
    pub action: Action,
}

/// A complete scenario: deterministic given these fields + the fixture.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    /// Capacity factor per device; index 0 is the central node (1.0).
    pub capacities: Vec<f64>,
    /// Total training batches to complete.
    pub batches: u64,
    pub seed: u64,

    // --- training hyper-parameters ---
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Max in-flight batches (1 = fully serialized, quiesces between
    /// batches — the setting under which recovery is *exact*).
    pub inflight: usize,
    /// Weight aggregation interval factor (0 disables).
    pub agg_k: u32,
    /// Chain/global replication periods in batches (0 disables).
    pub chain_every: u64,
    pub global_every: u64,

    // --- schedules ---
    /// Dynamic re-partition: (first at batch, then every) — None disables.
    pub repartition: Option<(u64, u64)>,
    /// Central-node gradient timeout (virtual).
    pub fault_timeout: Duration,
    /// How long the coordinator waits for probe acks (virtual).
    pub probe_window: Duration,
    /// How long a redistribution may stall before re-probing (virtual) —
    /// this is what makes a mid-redistribution failure recoverable.
    pub redist_window: Duration,

    // --- virtual network + compute model ---
    pub bandwidth_bps: f64,
    pub latency: Duration,
    /// Modeled compute cost; per-batch stage time = flops × this × C_i.
    pub ns_per_flop: f64,

    /// Wire-compression policy for the whole cluster. `Off` keeps every
    /// tensor f32 with the pre-compression `byte_len` accounting and
    /// numerics, so all pre-compression scenario traces are unchanged.
    /// `Adaptive` starts at tier off and walks the ladder per measured
    /// bandwidth ([`Scenario::adaptive`] thresholds, DESIGN.md §10).
    pub compression: Compression,
    /// Tier thresholds for `Compression::Adaptive` (ignored otherwise).
    pub adaptive: AdaptiveThresholds,
    /// Periodic link re-measurement cadence in batches (0 = only the
    /// one-shot init probe — the default, so existing traces are
    /// byte-identical). The adaptive policy needs this to observe
    /// scripted `SetBandwidth` degradation.
    pub bw_probe_every: u64,
    /// Fixed payload of those probes; 0 (default) auto-sizes from the
    /// last measurement (see `pipeline::stage::BW_PROBE_TARGET_S`).
    pub bw_probe_bytes: u64,

    /// Central-node checkpoint period in committed batches (paper
    /// §III-E), written to the harness's in-memory sink. 0 disables
    /// checkpointing entirely — the default, so every scenario that
    /// predates central-restart runs byte-identically.
    pub checkpoint_every: u64,

    pub events: Vec<ScriptEvent>,
}

impl Scenario {
    /// A conservative base: 3 devices, serialized pipeline, replicate
    /// every batch, momentum off — the configuration under which
    /// recovery is mathematically exact (see `rust/tests/scenarios/`).
    pub fn exact_recovery(name: &str, n_devices: usize, batches: u64) -> Scenario {
        Scenario {
            name: name.to_string(),
            capacities: vec![1.0; n_devices],
            batches,
            seed: 7,
            lr: 0.05,
            momentum: 0.0,
            weight_decay: 0.0,
            inflight: 1,
            agg_k: 0,
            chain_every: 1,
            global_every: 1,
            repartition: None,
            fault_timeout: Duration::from_millis(200),
            probe_window: Duration::from_millis(50),
            redist_window: Duration::from_secs(2),
            bandwidth_bps: 1e8,
            latency: Duration::from_micros(100),
            ns_per_flop: 1.0,
            compression: Compression::Off,
            adaptive: AdaptiveThresholds::default(),
            bw_probe_every: 0,
            bw_probe_bytes: 0,
            checkpoint_every: 0,
            events: vec![],
        }
    }

    /// A pipelined base (inflight = n_stages, momentum on, aggregation
    /// on): realistic async-1F1B behavior; recovery is asserted for
    /// continuity + determinism rather than exact weight equality.
    pub fn pipelined(name: &str, n_devices: usize, batches: u64) -> Scenario {
        Scenario {
            momentum: 0.9,
            weight_decay: 4e-5,
            inflight: n_devices,
            agg_k: 4,
            chain_every: 5,
            global_every: 10,
            ..Scenario::exact_recovery(name, n_devices, batches)
        }
    }

    pub fn n_devices(&self) -> usize {
        self.capacities.len()
    }

    pub fn with_events(mut self, events: Vec<ScriptEvent>) -> Scenario {
        self.events = events;
        self
    }

    pub fn with_compression(mut self, compression: Compression) -> Scenario {
        self.compression = compression;
        self
    }

    /// Set the adaptive-tier thresholds (implies nothing unless
    /// `compression == Adaptive`).
    pub fn with_adaptive(mut self, thresholds: AdaptiveThresholds) -> Scenario {
        self.adaptive = thresholds;
        self
    }

    /// Re-measure link bandwidth every `every` batches (0 = off).
    pub fn with_bw_probe_every(mut self, every: u64) -> Scenario {
        self.bw_probe_every = every;
        self
    }

    /// Checkpoint every `every` committed batches (0 = off).
    pub fn with_checkpoint(mut self, every: u64) -> Scenario {
        self.checkpoint_every = every;
        self
    }

    /// Sanity checks the runner relies on.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_devices() >= 2, "scenarios need at least 2 devices");
        anyhow::ensure!(self.capacities[0] == 1.0, "central capacity must be 1.0");
        anyhow::ensure!(self.batches > 0 && self.inflight > 0, "empty training run");
        if self.compression == Compression::Adaptive {
            self.adaptive.validate()?;
        }
        let mut unrescued_central_kill = false;
        let mut has_at_restart = false;
        for e in &self.events {
            let dev = match &e.action {
                Action::Kill { device, .. } => *device,
                Action::SetCapacity { device, .. } => *device,
                Action::SetBandwidth { bps } => {
                    anyhow::ensure!(
                        bps.is_finite() && *bps > 0.0,
                        "SetBandwidth needs a positive finite rate (got {bps})"
                    );
                    continue;
                }
                Action::KillCentral { restart_after } => {
                    if restart_after.is_none() {
                        unrescued_central_kill = true;
                    }
                    continue;
                }
                Action::RestartCentral => {
                    // only an At trigger can fire while the central node
                    // is down: batches don't complete and redistributions
                    // don't start without a coordinator, so a batch- or
                    // redist-triggered restart can never rescue a kill
                    anyhow::ensure!(
                        matches!(e.at, Trigger::At(_)),
                        "RestartCentral must use an At(..) trigger (got {:?}): batch and \
                         redistribution triggers cannot fire while the central node is down",
                        e.at
                    );
                    has_at_restart = true;
                    continue;
                }
            };
            anyhow::ensure!(
                dev != 0 && dev < self.n_devices(),
                "script actions must target a worker (got device {dev})"
            );
        }
        anyhow::ensure!(
            !unrescued_central_kill || has_at_restart,
            "KillCentral without restart_after needs an At(..)-triggered RestartCentral \
             event (a dead coordinator can never finish the run); note an At time before \
             the kill still deadlocks — prefer KillCentral{{restart_after}}"
        );
        Ok(())
    }
}

/// Seeded chaos-schedule generator (ROADMAP: randomized-but-seeded
/// kill/slowdown coverage). Produces `n_events` scripted events at
/// strictly increasing, well-spaced batch marks:
///
/// * the first event is always a kill, so every chaos run exercises the
///   fault handler at least once;
/// * every kill revives within 10–60 virtual ms — far inside the default
///   200 ms gradient timeout, so the probe round finds the worker
///   alive-but-fresh (paper case 2) and the worker list never shrinks,
///   which keeps any generated schedule recoverable by construction;
/// * slowdowns draw a capacity factor in [1.5, 6.5].
///
/// The schedule is a pure function of `(n_devices, batches, n_events,
/// seed)`: two runs of one chaos scenario replay the identical timeline,
/// and the scenario suite asserts their traces are byte-identical.
pub fn chaos_events(
    n_devices: usize,
    batches: u64,
    n_events: usize,
    seed: u64,
) -> Vec<ScriptEvent> {
    assert!(n_devices >= 2, "chaos needs at least one worker");
    let mut rng = Rng::new(seed ^ 0xC4A0_5EED);
    let mut events = Vec::with_capacity(n_events);
    // leave headroom at both ends so every fault has batches left to
    // replay and the run can still quiesce
    let mut batch = 4 + rng.below(4);
    for i in 0..n_events {
        if batch + 5 >= batches {
            break;
        }
        let device = 1 + rng.below((n_devices - 1) as u64) as usize;
        let action = if i == 0 || rng.below(3) < 2 {
            Action::Kill {
                device,
                revive_after: Some(Duration::from_millis(10 + rng.below(51))),
            }
        } else {
            Action::SetCapacity { device, capacity: 1.5 + rng.next_f64() * 5.0 }
        };
        events.push(ScriptEvent { at: Trigger::BatchDone(batch), action });
        batch += 6 + rng.below(8);
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_schedule_is_seed_deterministic() {
        let a = chaos_events(4, 60, 5, 7);
        let b = chaos_events(4, 60, 5, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.action, y.action);
        }
        let c = chaos_events(4, 60, 5, 8);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.at != y.at || x.action != y.action),
            "different seeds should produce different schedules"
        );
    }

    #[test]
    fn chaos_schedule_is_recoverable_by_construction() {
        for seed in 0..32u64 {
            let evs = chaos_events(4, 80, 6, seed);
            assert!(!evs.is_empty());
            assert!(
                matches!(evs[0].action, Action::Kill { .. }),
                "seed {seed}: first event must be a kill"
            );
            let mut last = 0u64;
            for e in &evs {
                let Trigger::BatchDone(b) = e.at else {
                    panic!("chaos triggers are batch-based")
                };
                assert!(b > last || last == 0, "marks strictly increase");
                assert!(b + 5 < 80, "headroom at the end of the run");
                last = b;
                match &e.action {
                    Action::Kill { device, revive_after } => {
                        assert!((1..4).contains(device));
                        let r = revive_after.expect("chaos kills always revive");
                        assert!(r <= Duration::from_millis(60), "inside the fault timeout");
                    }
                    Action::SetCapacity { device, capacity } => {
                        assert!((1..4).contains(device));
                        assert!((1.5..=6.5).contains(capacity));
                    }
                    other => panic!("chaos only kills and slows workers, got {other:?}"),
                }
            }
            // every generated schedule passes scenario validation
            Scenario::exact_recovery("chaos-gen", 4, 80).with_events(evs).validate().unwrap();
        }
    }

    #[test]
    fn validate_enforces_central_restart_rescue_rules() {
        let base = Scenario::exact_recovery("v", 3, 20);
        // an unrescued central kill can never finish the run
        let sc = base.clone().with_events(vec![ScriptEvent {
            at: Trigger::BatchDone(5),
            action: Action::KillCentral { restart_after: None },
        }]);
        assert!(sc.validate().is_err());
        // inline restart_after rescues
        let sc = base.clone().with_events(vec![ScriptEvent {
            at: Trigger::BatchDone(5),
            action: Action::KillCentral { restart_after: Some(Duration::from_millis(10)) },
        }]);
        sc.validate().unwrap();
        // an At-triggered RestartCentral rescues
        let sc = base.clone().with_events(vec![
            ScriptEvent {
                at: Trigger::BatchDone(5),
                action: Action::KillCentral { restart_after: None },
            },
            ScriptEvent {
                at: Trigger::At(Duration::from_secs(2)),
                action: Action::RestartCentral,
            },
        ]);
        sc.validate().unwrap();
        // a batch-triggered RestartCentral can never fire while the
        // central is down — reject it outright
        let sc = base.with_events(vec![
            ScriptEvent {
                at: Trigger::BatchDone(5),
                action: Action::KillCentral { restart_after: None },
            },
            ScriptEvent { at: Trigger::BatchDone(9), action: Action::RestartCentral },
        ]);
        assert!(sc.validate().is_err());
    }

    #[test]
    fn chaos_first_event_is_always_a_kill() {
        for n_devices in 2..=6usize {
            for seed in 0..16u64 {
                let evs = chaos_events(n_devices, 100, 5, seed);
                assert!(!evs.is_empty(), "n={n_devices} seed={seed}: empty schedule");
                match &evs[0].action {
                    Action::Kill { device, revive_after } => {
                        assert!(
                            (1..n_devices).contains(device),
                            "n={n_devices} seed={seed}: kill targets a worker"
                        );
                        assert!(revive_after.is_some());
                    }
                    other => panic!("n={n_devices} seed={seed}: first event {other:?} not a kill"),
                }
            }
        }
    }

    #[test]
    fn chaos_revives_land_inside_the_fault_timeout() {
        // the documented band is 10–60 ms — far inside the 200 ms
        // gradient timeout of the exact-recovery base, so a chaos kill is
        // always observed as a case-2 restart, never a lost worker
        let timeout = Scenario::exact_recovery("probe", 4, 10).fault_timeout;
        for seed in 0..64u64 {
            for e in chaos_events(4, 120, 8, seed) {
                if let Action::Kill { revive_after, .. } = &e.action {
                    let r = revive_after.expect("chaos kills always revive");
                    assert!(
                        r >= Duration::from_millis(10) && r <= Duration::from_millis(60),
                        "seed {seed}: revive {r:?} outside the documented 10-60ms band"
                    );
                    assert!(r < timeout, "seed {seed}: revive {r:?} past the {timeout:?} timeout");
                }
            }
        }
    }

    #[test]
    fn chaos_capacities_stay_inside_the_documented_band() {
        let mut seen_slowdown = false;
        for seed in 0..64u64 {
            for e in chaos_events(5, 120, 8, seed) {
                if let Action::SetCapacity { capacity, .. } = &e.action {
                    seen_slowdown = true;
                    assert!(
                        (1.5..=6.5).contains(capacity),
                        "seed {seed}: capacity {capacity} outside [1.5, 6.5]"
                    );
                }
            }
        }
        assert!(seen_slowdown, "64 seeds x 8 events never drew a slowdown");
    }

    #[test]
    fn chaos_marks_strictly_increase_with_headroom() {
        for seed in 0..64u64 {
            let batches = 90u64;
            let mut prev: Option<u64> = None;
            for e in chaos_events(4, batches, 10, seed) {
                let Trigger::BatchDone(b) = e.at else {
                    panic!("seed {seed}: chaos triggers are batch-based")
                };
                assert!(b >= 4, "seed {seed}: mark {b} leaves no warm-up headroom");
                assert!(b + 5 < batches, "seed {seed}: mark {b} leaves no quiesce headroom");
                if let Some(p) = prev {
                    assert!(b > p, "seed {seed}: marks must strictly increase ({p} -> {b})");
                    assert!(b - p >= 6, "seed {seed}: marks too close ({p} -> {b})");
                }
                prev = Some(b);
            }
        }
    }
}
