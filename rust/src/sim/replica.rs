//! Hybrid pipeline + data parallelism driver: R replica chains training
//! disjoint round-robin batch shards, periodically averaged through the
//! central node (DESIGN.md §14).
//!
//! Each chain is modeled as ONE fused [`StageWorker`] owning every block
//! — the single-stage `forward_train` path runs forward + loss +
//! backward + SGD synchronously, so chain-internal pipelining is
//! abstracted into the chain's aggregate capacity
//! ([`crate::partition::chain_cost`]) while the cross-replica protocol
//! (shards, sync barrier, whole-replica death) is simulated exactly.
//! This trades per-hop fidelity inside a chain for bit-exact weight
//! math across chains, which is what the replica tests pin down.
//!
//! Determinism contract (mirrors the single-chain runner):
//! * every chain boots from the same manifest weights;
//! * events pop in `(time, seq)` order from the shared [`EventQueue`];
//! * the averaging fold visits contributors in ascending chain order
//!   and multiplies by the reciprocal once — the scenario tests
//!   recompute the same fold and demand bit-identity;
//! * scripted [`Action::KillReplica`] fires when its sync round would
//!   first open, BEFORE the barrier's `SyncDue`, so a victim never
//!   contributes partials to the round that buries it.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::DeviceConfig;
use crate::coordinator::core::{PhaseConfig, PhaseEffect, PhaseInput, PhaseMachine};
use crate::data::SynthVision;
use crate::device::SimDevice;
use crate::manifest::Manifest;
use crate::model::BlockParams;
use crate::net::message::{DeviceId, Message, TrainInit, WireTensor};
use crate::net::quant::{weight_channel_hint, ChannelHint};
use crate::net::Transport;
use crate::partition::{chain_cost, replica_plan, validate_replica_plan};
use crate::pipeline::{StageWorker, StepKind};
use crate::replication;
use crate::runtime::{load_all_blocks_native, HostTensor};
use crate::sim::clock::{SharedClock, VirtualClock};
use crate::sim::queue::EventQueue;
use crate::sim::runner::ScenarioOutcome;
use crate::sim::script::{Action, Scenario, Trigger};

// ---------------------------------------------------------------------
// sync records
// ---------------------------------------------------------------------

/// What one resolved sync round averaged: the per-chain weights exactly
/// as the central fold saw them (decoded partials for chains > 0, the
/// local f32 store for chain 0) and the averaged result it installed.
/// `post` is ALWAYS the bitwise average of `pre` — the scenario tests
/// recompute the fold from `pre` and compare bits.
#[derive(Debug, Clone)]
pub struct SyncRecord {
    pub round: u64,
    /// chain -> block -> pre-averaging parameters.
    pub pre: BTreeMap<usize, BTreeMap<usize, BlockParams>>,
    /// block -> averaged parameters (what chain 0 holds afterwards).
    pub post: BTreeMap<usize, BlockParams>,
}

// ---------------------------------------------------------------------
// null transport
// ---------------------------------------------------------------------

/// Fused chain workers must never talk on their own: labels are fed
/// in-process and the sync protocol is driven by this runner. Any send
/// is a modeling bug, counted here and surfaced as a hard error.
struct NullNet {
    n: usize,
    sends: Mutex<u64>,
}

#[derive(Clone)]
struct NullHandle {
    id: DeviceId,
    net: Arc<NullNet>,
}

impl Transport for NullHandle {
    fn my_id(&self) -> DeviceId {
        self.id
    }

    fn send(&self, _to: DeviceId, _msg: Message) -> Result<()> {
        *self.net.sends.lock().unwrap() += 1;
        Ok(())
    }

    fn recv_timeout(&self, _timeout: Duration) -> Option<(DeviceId, Message)> {
        None
    }

    fn n_devices(&self) -> usize {
        self.net.n
    }
}

// ---------------------------------------------------------------------
// driver state
// ---------------------------------------------------------------------

enum REv {
    /// The fused chain finished its in-flight batch.
    ChainDone { chain: usize, batch: u64, loss: f32 },
    /// One block of a chain's sync partial reached the central node.
    PartialArrive { chain: usize, block_id: usize, tensors: Vec<WireTensor> },
    /// One block of the averaged weights reached a chain head.
    InstallArrive { chain: usize, block_id: usize, tensors: Vec<WireTensor> },
}

struct Chain {
    head: DeviceId,
    /// Batches still to train, in shard order (absorbed orphans append).
    shard: VecDeque<u64>,
    trained: u64,
    shard_len: u64,
    dead: bool,
    /// A batch is in flight (its ChainDone is queued).
    busy: bool,
}

/// Run a replicated scenario (`Scenario::replicas > 1`). Reached through
/// [`crate::sim::run_scenario`]; R = 1 never enters this file.
pub fn run_replica_scenario(scenario: &Scenario, model_dir: &Path) -> Result<ScenarioOutcome> {
    scenario.validate()?;
    let manifest = Arc::new(Manifest::load(model_dir)?);
    let n = scenario.n_devices();
    let plan = replica_plan(&scenario.capacities, scenario.replicas, scenario.batches);
    validate_replica_plan(&plan, n, scenario.batches)
        .map_err(|e| anyhow!("replica plan invalid: {e}"))?;

    let clock = VirtualClock::shared();
    let shared: SharedClock = clock.clone();
    let net = Arc::new(NullNet { n, sends: Mutex::new(0) });

    let nb = manifest.n_blocks();
    let mut workers = Vec::with_capacity(plan.chains.len());
    let mut handles = Vec::with_capacity(plan.chains.len());
    let mut chains = Vec::with_capacity(plan.chains.len());
    for (c, devs) in plan.chains.iter().enumerate() {
        let head = devs[0];
        let caps: Vec<f64> = devs.iter().map(|&d| scenario.capacities[d]).collect();
        let cfg = DeviceConfig { capacity: chain_cost(&caps), ..DeviceConfig::default() };
        let sim = SimDevice::with_clock(
            cfg,
            scenario.seed ^ (head as u64).wrapping_mul(0x9E3779B9),
            shared.clone(),
            Some(scenario.ns_per_flop),
        );
        let blocks = load_all_blocks_native(&manifest)?;
        let mut w = StageWorker::new(head, manifest.clone(), blocks, sim, None);
        w.set_clock(shared.clone());
        w.apply_init(&TrainInit {
            committed_forward: -1,
            committed_backward: -1,
            lr: scenario.lr,
            momentum: scenario.momentum,
            weight_decay: scenario.weight_decay,
            epochs: 1,
            batches_per_epoch: scenario.batches,
            ranges: vec![(0, nb - 1)],
            worker_list: vec![head],
            agg_k: 0,
            chain_every: 0,
            global_every: 0,
            status: 0,
            compression: scenario.compression,
            bw_probe_every: 0,
            bw_probe_bytes: 0,
            tier_floor: scenario.adaptive.tier_floor,
            tier_ceiling: scenario.adaptive.tier_ceiling,
            replica_epoch: 0,
            worker_quota: 0,
            replicas: scenario.replicas as u64,
            sync_every: scenario.sync_every,
        })?;
        workers.push(w);
        handles.push(NullHandle { id: head, net: net.clone() });
        let shard: VecDeque<u64> = plan.shard_assignment[c].iter().copied().collect();
        let shard_len = shard.len() as u64;
        chains.push(Chain { head, shard, trained: 0, shard_len, dead: false, busy: false });
    }

    let dim: usize = manifest.input_shape.iter().skip(1).product();
    let classes = manifest.n_classes.context("fixture manifest missing n_classes")?;
    let hints: Vec<Vec<ChannelHint>> = (0..nb)
        .map(|b| {
            manifest.blocks[b]
                .params
                .iter()
                .map(|p| weight_channel_hint(&p.shape, p.size))
                .collect()
        })
        .collect();

    let r = plan.chains.len() as u64;
    let event_ceiling = 1_000_000
        + scenario
            .batches
            .saturating_mul(16)
            .saturating_add((scenario.batches / scenario.sync_every.max(1) + 2) * r * nb as u64 * 4);

    let driver = RDriver {
        sc: scenario,
        manifest: manifest.clone(),
        clock,
        net,
        queue: EventQueue::with_capacity(n, 4 * n + 64),
        workers,
        handles,
        chains,
        data: SynthVision::new(dim, classes, 0.5, scenario.seed, 0),
        machine: PhaseMachine::new(PhaseConfig {
            probe_window: scenario.probe_window,
            redist_window: scenario.redist_window,
        }),
        hints,
        round: 1,
        syncing: false,
        finished: false,
        pre_partials: BTreeMap::new(),
        pending_install: vec![BTreeMap::new(); plan.chains.len()],
        link_free: HashMap::new(),
        bytes_total: 0,
        losses: BTreeMap::new(),
        trace: Vec::with_capacity(scenario.batches as usize * 3 + 64),
        sync_records: Vec::new(),
        fired: vec![false; scenario.events.len()],
        recoveries: 0,
        events_processed: 0,
        event_ceiling,
        plan_chains: plan.chains,
    };
    driver.run()
}

struct RDriver<'a> {
    sc: &'a Scenario,
    manifest: Arc<Manifest>,
    clock: Arc<VirtualClock>,
    net: Arc<NullNet>,
    queue: EventQueue<REv>,
    /// One fused worker per chain (indexed by chain, NOT device).
    workers: Vec<StageWorker>,
    handles: Vec<NullHandle>,
    chains: Vec<Chain>,
    data: SynthVision,
    /// The shared coordinator phase machine drives the sync barrier:
    /// Training -> Syncing on `SyncDue`, back on a resolving `Poll`.
    machine: PhaseMachine,
    /// Per-block quantization hints (same derivation as the workers').
    hints: Vec<Vec<ChannelHint>>,
    /// Next unresolved sync round (1-based).
    round: u64,
    syncing: bool,
    /// All live chains exhausted their shards and the final round
    /// resolved — no further barriers open.
    finished: bool,
    /// chain -> block -> decoded uplink partial for the open round.
    pre_partials: BTreeMap<usize, BTreeMap<usize, BlockParams>>,
    /// Per chain: blocks of the averaged broadcast still being received.
    pending_install: Vec<BTreeMap<usize, BlockParams>>,
    /// Per-directed-link serialization, same pricing as `VirtualNet`.
    link_free: HashMap<(DeviceId, DeviceId), Duration>,
    bytes_total: u64,
    losses: BTreeMap<u64, f32>,
    trace: Vec<String>,
    sync_records: Vec<SyncRecord>,
    fired: Vec<bool>,
    recoveries: usize,
    events_processed: u64,
    event_ceiling: u64,
    plan_chains: Vec<Vec<usize>>,
}

impl RDriver<'_> {
    fn trace_line(&mut self, at: Duration, args: std::fmt::Arguments<'_>) {
        use std::fmt::Write;
        let mut line = String::with_capacity(48);
        let _ = write!(line, "[{:>13}ns] {}", at.as_nanos(), args);
        self.trace.push(line);
    }

    /// Price one runner-driven control message on the `from -> to` link:
    /// identical arithmetic to `VirtualNet::send` (serialization via
    /// `link_free`, then latency + bytes/bandwidth).
    fn price_send(&mut self, from: DeviceId, to: DeviceId, depart: Duration, msg: &Message) -> Duration {
        let bytes = msg.byte_len() as u64;
        self.bytes_total += bytes;
        let free = self.link_free.get(&(from, to)).copied().unwrap_or(Duration::ZERO);
        let start = depart.max(free);
        let transfer = Duration::from_secs_f64(bytes as f64 / self.sc.link_bw_for(from, to));
        self.link_free.insert((from, to), start + transfer);
        start + self.sc.latency + transfer
    }

    /// Training quota for `chain` under the current round: shards are
    /// cut into `sync_every`-batch slices, capped by the shard itself.
    fn round_target(&self, chain: usize) -> u64 {
        self.chains[chain].shard_len.min(self.round * self.sc.sync_every)
    }

    // -------------------------------------------------- run loop

    fn run(mut self) -> Result<ScenarioOutcome> {
        for (c, devs) in self.plan_chains.clone().iter().enumerate() {
            let shard_len = self.chains[c].shard_len;
            self.trace_line(
                Duration::ZERO,
                format_args!("plan: chain={c} devices={devs:?} shard_len={shard_len}"),
            );
        }
        self.machine.step(PhaseInput::TrainingStarted)?;
        for c in 0..self.chains.len() {
            self.advance(c, Duration::ZERO)?;
        }
        while let Some((at, ev)) = self.queue.pop() {
            self.events_processed += 1;
            if self.events_processed > self.event_ceiling {
                bail!("replica event ceiling exceeded ({}) — livelock", self.event_ceiling);
            }
            self.clock.set(at);
            match ev {
                REv::ChainDone { chain, batch, loss } => self.on_chain_done(chain, batch, loss, at)?,
                REv::PartialArrive { chain, block_id, tensors } => {
                    self.on_partial(chain, block_id, tensors, at)?
                }
                REv::InstallArrive { chain, block_id, tensors } => {
                    self.on_install(chain, block_id, tensors, at)?
                }
            }
        }
        if !self.finished {
            bail!("replica run drained its event queue before the final sync resolved (deadlock)");
        }
        for (c, ch) in self.chains.iter().enumerate() {
            if !ch.dead && ch.trained != ch.shard_len {
                bail!("chain {c} trained {}/{} shard batches", ch.trained, ch.shard_len);
            }
        }
        let stray = *self.net.sends.lock().unwrap();
        if stray != 0 {
            bail!("fused chain workers sent {stray} unexpected messages");
        }
        let end = self.clock.now();
        self.trace_line(end, format_args!("run complete"));
        let final_weights: BTreeMap<usize, BlockParams> =
            self.workers[0].params.blocks.iter().map(|(&b, bp)| (b, bp.clone())).collect();
        if final_weights.len() != self.manifest.n_blocks() {
            bail!(
                "chain 0 holds {}/{} blocks",
                final_weights.len(),
                self.manifest.n_blocks()
            );
        }
        Ok(ScenarioOutcome {
            trace: self.trace,
            losses: self.losses,
            final_weights,
            redists: Vec::new(),
            recoveries: self.recoveries,
            checkpoints: 0,
            restarts: 0,
            virtual_ms: end.as_secs_f64() * 1e3,
            net_bytes: self.bytes_total,
            events: self.events_processed,
            phase_log: self.machine.take_log(),
            sync_records: self.sync_records,
        })
    }

    // -------------------------------------------------- training

    /// Move `chain` forward: train if it still owes batches this round,
    /// otherwise see whether the barrier can open.
    fn advance(&mut self, chain: usize, t: Duration) -> Result<()> {
        if self.finished || self.syncing {
            return Ok(());
        }
        let ch = &self.chains[chain];
        if ch.dead || ch.busy {
            return Ok(());
        }
        if ch.trained < self.round_target(chain) {
            self.start_batch(chain, t)
        } else {
            self.maybe_sync(t)
        }
    }

    fn start_batch(&mut self, chain: usize, t: Duration) -> Result<()> {
        let batch = self.chains[chain]
            .shard
            .pop_front()
            .with_context(|| format!("chain {chain} has no shard batch to start"))?;
        let data = self.data.batch(0, batch, self.manifest.batch_size);
        let h = self.handles[chain].clone();
        let head = self.chains[chain].head;
        let labels = Message::Labels { batch, is_eval: false, data: data.labels.clone() };
        self.workers[chain].handle_message(&h, head, labels)?;
        let kind = StepKind::Forward { batch, is_eval: false };
        let flops = self.workers[chain].step_flops(&kind);
        let cost = self.workers[chain]
            .sim
            .modeled_cost(flops)
            .unwrap_or(Duration::from_micros(1));
        let version = self.workers[chain].version;
        // run the fused step NOW (the math is interleave-independent:
        // each chain touches only its own worker + its own shard), but
        // surface the completion at the modeled finish time
        let x = HostTensor::F32(data.x_f32.into());
        let cb = self.workers[chain]
            .forward_train(&h, batch, version, x)?
            .context("fused chain worker did not complete its batch synchronously")?;
        self.trace_line(t, format_args!("chain={chain} inject batch={batch}"));
        self.chains[chain].busy = true;
        self.queue.push(t + cost, REv::ChainDone { chain, batch, loss: cb.loss });
        Ok(())
    }

    fn on_chain_done(&mut self, chain: usize, batch: u64, loss: f32, t: Duration) -> Result<()> {
        self.chains[chain].busy = false;
        self.chains[chain].trained += 1;
        self.trace_line(
            t,
            format_args!("chain={chain} complete batch={batch} loss_bits={:08x}", loss.to_bits()),
        );
        self.losses.insert(batch, loss);
        self.advance(chain, t)
    }

    // -------------------------------------------------- sync barrier

    /// Open the barrier iff every live chain met its round target.
    /// Scripted whole-replica kills scheduled for this round fire here,
    /// BEFORE `SyncDue` — absorbing survivors may get new quota, which
    /// simply postpones the barrier.
    fn maybe_sync(&mut self, t: Duration) -> Result<()> {
        if self.syncing || self.finished {
            return Ok(());
        }
        if (0..self.chains.len())
            .any(|c| !self.chains[c].dead && self.chains[c].trained < self.round_target(c))
        {
            return Ok(());
        }
        self.fire_round_kills(t)?;
        let lagging: Vec<usize> = (0..self.chains.len())
            .filter(|&c| !self.chains[c].dead && self.chains[c].trained < self.round_target(c))
            .collect();
        if !lagging.is_empty() {
            for c in lagging {
                self.advance(c, t)?;
            }
            return Ok(());
        }
        self.syncing = true;
        let expect: BTreeSet<usize> =
            (1..self.chains.len()).filter(|&c| !self.chains[c].dead).collect();
        let round = self.round;
        let (_, effects) = self.machine.step(PhaseInput::SyncDue { round, expect })?;
        for eff in effects {
            self.dispatch_effect(eff, t)?;
        }
        // resolves immediately when chain 0 is the only survivor
        self.poll_machine(t)
    }

    fn fire_round_kills(&mut self, t: Duration) -> Result<()> {
        let sc = self.sc;
        let due: Vec<(usize, usize)> = sc
            .events
            .iter()
            .enumerate()
            .filter(|&(i, _)| !self.fired[i])
            .filter_map(|(i, e)| match (&e.at, &e.action) {
                (Trigger::SyncRound(r), Action::KillReplica { replica }) if *r == self.round => {
                    Some((i, *replica))
                }
                _ => None,
            })
            .collect();
        for (i, victim) in due {
            self.fired[i] = true;
            self.kill_replica(victim, t)?;
        }
        Ok(())
    }

    /// Whole-replica death (the case-3 analogue scoped to one chain):
    /// the victim's untrained shard remainder is redistributed
    /// round-robin over the surviving chains in ascending order. Its
    /// trained-but-unsynced batches are LOST gradient contributions —
    /// their losses stay in the trace, matching FTPipeHD's
    /// commit-at-sync semantics.
    fn kill_replica(&mut self, victim: usize, t: Duration) -> Result<()> {
        if victim == 0 || victim >= self.chains.len() {
            bail!("KillReplica {victim} out of range (chain 0 hosts the central node)");
        }
        if self.chains[victim].dead {
            bail!("KillReplica {victim} hit an already-dead replica");
        }
        self.chains[victim].dead = true;
        self.recoveries += 1;
        let orphans: Vec<u64> = self.chains[victim].shard.drain(..).collect();
        self.chains[victim].shard_len = self.chains[victim].trained;
        self.trace_line(
            t,
            format_args!("script: kill replica {victim} orphans={}", orphans.len()),
        );
        let live: Vec<usize> = (0..self.chains.len()).filter(|&c| !self.chains[c].dead).collect();
        for (k, &b) in orphans.iter().enumerate() {
            let c = live[k % live.len()];
            self.chains[c].shard.push_back(b);
            self.chains[c].shard_len += 1;
        }
        for &c in &live {
            self.trace_line(
                t,
                format_args!("absorb: chain={c} shard_len={}", self.chains[c].shard_len),
            );
        }
        Ok(())
    }

    fn poll_machine(&mut self, t: Duration) -> Result<()> {
        let (_, effects) = self.machine.step(PhaseInput::Poll {
            now: t,
            overdue: None,
            inflight: 0,
            peers: 0,
            local_fetch_done: true,
        })?;
        for eff in effects {
            self.dispatch_effect(eff, t)?;
        }
        Ok(())
    }

    fn dispatch_effect(&mut self, eff: PhaseEffect, t: Duration) -> Result<()> {
        match eff {
            PhaseEffect::BeginSync { round } => self.begin_sync(round, t),
            PhaseEffect::ResolveSync { round, chains } => self.resolve_sync(round, chains, t),
            other => bail!("replica runner received unexpected effect {}", other.kind()),
        }
    }

    /// Uplink: every expected chain ships its full weight set to the
    /// central node, one [`Message::ReplicaSync`] per block, coded at
    /// the link tier's replica coding (lossy tiers allowed — the fold
    /// averages whatever arrived, DESIGN.md §14).
    fn begin_sync(&mut self, round: u64, t: Duration) -> Result<()> {
        self.trace_line(t, format_args!("sync: round={round} begin"));
        let up = self.sc.compression.initial_tier().replica_coding();
        for chain in 1..self.chains.len() {
            if self.chains[chain].dead {
                continue;
            }
            let head = self.chains[chain].head;
            for b in 0..self.manifest.n_blocks() {
                let bp = self.workers[chain]
                    .params
                    .blocks
                    .get(&b)
                    .with_context(|| format!("chain {chain} missing block {b}"))?;
                let tensors = replication::block_to_wire_coded(bp, &self.hints[b], up);
                let msg = Message::ReplicaSync { round, block_id: b, tensors };
                let arrive = self.price_send(head, 0, t, &msg);
                let Message::ReplicaSync { tensors, .. } = msg else { unreachable!() };
                self.queue.push(arrive, REv::PartialArrive { chain, block_id: b, tensors });
            }
        }
        Ok(())
    }

    fn on_partial(
        &mut self,
        chain: usize,
        block_id: usize,
        tensors: Vec<WireTensor>,
        t: Duration,
    ) -> Result<()> {
        let bp = replication::block_from_wire(tensors);
        let entry = self.pre_partials.entry(chain).or_default();
        entry.insert(block_id, bp);
        if entry.len() == self.manifest.n_blocks() {
            self.trace_line(t, format_args!("sync: partial chain={chain} complete"));
            self.machine.step(PhaseInput::SyncPartial { chain })?;
            self.poll_machine(t)?;
        }
        Ok(())
    }

    /// The barrier resolved: fold contributor weights (chain 0's local
    /// f32 store plus every decoded partial) in ascending chain order,
    /// multiply by the reciprocal once, install into chain 0, record,
    /// broadcast. Momentum/SGD state is deliberately NOT averaged —
    /// weights only (DESIGN.md §14).
    fn resolve_sync(&mut self, round: u64, chains_done: BTreeSet<usize>, t: Duration) -> Result<()> {
        let mut pre = std::mem::take(&mut self.pre_partials);
        pre.insert(0, self.workers[0].params.blocks.clone());
        for c in &chains_done {
            if !pre.contains_key(c) {
                bail!("sync round {round} resolved without a partial from chain {c}");
            }
        }
        let inv = 1.0f32 / pre.len() as f32;
        let nb = self.manifest.n_blocks();
        let mut post: BTreeMap<usize, BlockParams> = BTreeMap::new();
        for b in 0..nb {
            let nt = self.manifest.blocks[b].params.len();
            let mut acc: Vec<Vec<f32>> = Vec::with_capacity(nt);
            for k in 0..nt {
                let mut sum = vec![0.0f32; self.manifest.blocks[b].params[k].size];
                for blocks in pre.values() {
                    let bp = blocks
                        .get(&b)
                        .with_context(|| format!("sync partial missing block {b}"))?;
                    for (s, v) in sum.iter_mut().zip(bp.0[k].iter()) {
                        *s += *v;
                    }
                }
                for s in sum.iter_mut() {
                    *s *= inv;
                }
                acc.push(sum);
            }
            post.insert(b, BlockParams::from_vecs(acc));
        }
        for (&b, bp) in &post {
            self.workers[0].params.blocks.insert(b, bp.clone());
        }
        let contributors: Vec<usize> = pre.keys().copied().collect();
        self.trace_line(
            t,
            format_args!("sync: round={round} resolve chains={contributors:?}"),
        );
        self.sync_records.push(SyncRecord { round, pre, post: post.clone() });
        // downlink: averaged weights back to every surviving chain head
        // (restore coding — never Q4, same ceiling as fault restores)
        let down = self.sc.compression.initial_tier().restore_coding();
        for chain in 1..self.chains.len() {
            if self.chains[chain].dead {
                continue;
            }
            let head = self.chains[chain].head;
            for b in 0..nb {
                let tensors = replication::block_to_wire_coded(&post[&b], &self.hints[b], down);
                let msg = Message::ReplicaSync { round, block_id: b, tensors };
                let arrive = self.price_send(0, head, t, &msg);
                let Message::ReplicaSync { tensors, .. } = msg else { unreachable!() };
                self.queue.push(arrive, REv::InstallArrive { chain, block_id: b, tensors });
            }
        }
        self.syncing = false;
        if (0..self.chains.len())
            .all(|c| self.chains[c].dead || self.chains[c].trained == self.chains[c].shard_len)
        {
            self.finished = true;
        }
        self.round += 1;
        // chain 0 resumes immediately; the others resume on install
        self.advance(0, t)
    }

    fn on_install(
        &mut self,
        chain: usize,
        block_id: usize,
        tensors: Vec<WireTensor>,
        t: Duration,
    ) -> Result<()> {
        let bp = replication::block_from_wire(tensors);
        self.pending_install[chain].insert(block_id, bp);
        if self.pending_install[chain].len() == self.manifest.n_blocks() {
            let blocks = std::mem::take(&mut self.pending_install[chain]);
            for (b, bp) in blocks {
                self.workers[chain].params.blocks.insert(b, bp);
            }
            self.trace_line(t, format_args!("sync: install chain={chain}"));
            self.advance(chain, t)?;
        }
        Ok(())
    }
}
