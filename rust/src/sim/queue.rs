//! The O(log n) event engine behind the scenario runner (DESIGN.md §11).
//!
//! A binary min-heap keyed on `(virtual time, insertion seq)` — the exact
//! total order the old `BTreeMap<(Duration, u64), _>` queue popped in,
//! but with `O(log n)` push/pop and no node rebalancing — plus
//! *generation-counter tombstones*: purging every in-flight delivery
//! that touches a device (what `kill_central` needs) is one integer
//! bump instead of an `O(n)` queue rebuild. Tombstoned entries are
//! skipped silently on pop, so to every consumer the queue behaves as
//! if the purge had rebuilt it.
//!
//! The ordering contract is load-bearing: two scenario runs are
//! byte-identical **because** events at equal virtual times pop in
//! insertion order. `rust/tests/event_queue.rs` drives this engine and
//! a reference model of the old `BTreeMap` + `retain` queue through
//! random push/pop/purge schedules and asserts identical delivery
//! order.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Duration;

/// Monotonic insertion sequence — the tiebreaker that makes the event
/// order total (and therefore replayable) at equal virtual times.
pub type Seq = u64;

/// Link scope of a scoped entry, captured at push time: the endpoints
/// and the generation each endpoint had. A later `purge_device` bump
/// makes the stamp stale and the entry a tombstone.
#[derive(Debug, Clone, Copy)]
struct Stamp {
    from: u32,
    to: u32,
    from_gen: u32,
    to_gen: u32,
}

struct Entry<T> {
    at: Duration,
    seq: Seq,
    stamp: Option<Stamp>,
    ev: T,
}

// Ordered by (at, seq) only — seq is unique, so the order is total and
// the payload never needs to be comparable.
impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at.cmp(&other.at).then_with(|| self.seq.cmp(&other.seq))
    }
}

/// Min-heap event queue with per-device generation tombstones.
pub struct EventQueue<T> {
    heap: BinaryHeap<Reverse<Entry<T>>>,
    next_seq: Seq,
    /// Per-device purge generation; bumping one invalidates every
    /// scoped entry stamped with the old value.
    gen: Vec<u32>,
}

impl<T> EventQueue<T> {
    pub fn new(n_devices: usize) -> EventQueue<T> {
        EventQueue::with_capacity(n_devices, 0)
    }

    pub fn with_capacity(n_devices: usize, cap: usize) -> EventQueue<T> {
        EventQueue { heap: BinaryHeap::with_capacity(cap), next_seq: 0, gen: vec![0; n_devices] }
    }

    /// Entries in the heap, tombstones included (cheap; for budgeting).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    fn push_entry(&mut self, at: Duration, stamp: Option<Stamp>, ev: T) -> Seq {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Entry { at, seq, stamp, ev }));
        seq
    }

    /// Schedule an unscoped event — never tombstoned by any purge.
    pub fn push(&mut self, at: Duration, ev: T) -> Seq {
        self.push_entry(at, None, ev)
    }

    /// Schedule a delivery scoped to the directed link `from -> to`:
    /// a later [`EventQueue::purge_device`] of either endpoint drops it
    /// unpopped.
    pub fn push_scoped(&mut self, at: Duration, from: usize, to: usize, ev: T) -> Seq {
        let stamp = Stamp {
            from: from as u32,
            to: to as u32,
            from_gen: self.gen[from],
            to_gen: self.gen[to],
        };
        self.push_entry(at, Some(stamp), ev)
    }

    /// Drop every in-flight scoped entry touching device `d` (as sender
    /// or receiver) without scanning the queue: bump the device's
    /// generation so their stamps go stale. Unscoped entries and scoped
    /// entries pushed *after* the purge are untouched.
    pub fn purge_device(&mut self, d: usize) {
        self.gen[d] = self.gen[d].wrapping_add(1);
    }

    fn live(&self, stamp: &Option<Stamp>) -> bool {
        match stamp {
            None => true,
            Some(s) => {
                self.gen[s.from as usize] == s.from_gen && self.gen[s.to as usize] == s.to_gen
            }
        }
    }

    /// Pop the earliest live entry in `(time, seq)` order. Tombstones
    /// are discarded silently — they neither advance the caller's clock
    /// nor count as processed events, exactly like entries removed by
    /// the old purge-by-rebuild.
    pub fn pop(&mut self) -> Option<(Duration, T)> {
        while let Some(Reverse(e)) = self.heap.pop() {
            if self.live(&e.stamp) {
                return Some((e.at, e.ev));
            }
        }
        None
    }

    /// Live in-flight scoped deliveries counted by destination device —
    /// the overflow diagnostic's "per-device queue depth". `O(n)`, so
    /// only for error paths.
    pub fn depth_by_device(&self) -> Vec<usize> {
        let mut depth = vec![0usize; self.gen.len()];
        for Reverse(e) in self.heap.iter() {
            if let Some(s) = &e.stamp {
                if self.live(&e.stamp) {
                    depth[s.to as usize] += 1;
                }
            }
        }
        depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn pops_in_time_then_insertion_order() {
        let mut q: EventQueue<&str> = EventQueue::new(2);
        q.push(ms(5), "b");
        q.push(ms(1), "a");
        q.push(ms(5), "c"); // same time as "b": insertion order wins
        q.push(ms(3), "d");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "d", "b", "c"]);
    }

    #[test]
    fn purge_device_zero_drops_exactly_central_deliveries() {
        // the kill_central contract: every in-flight delivery to or
        // from device 0 dies with the process — nothing else moves
        let mut q: EventQueue<&str> = EventQueue::new(4);
        q.push_scoped(ms(1), 0, 2, "central->2");
        q.push_scoped(ms(2), 2, 0, "2->central");
        q.push_scoped(ms(3), 1, 2, "1->2");
        q.push(ms(4), "wake-3");
        q.push_scoped(ms(5), 3, 1, "3->1");
        q.purge_device(0);
        // a send made after the restart must survive the old purge
        q.push_scoped(ms(6), 0, 1, "central->1 post-restart");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["1->2", "wake-3", "3->1", "central->1 post-restart"]);
    }

    #[test]
    fn purge_is_per_device_and_repeatable() {
        let mut q: EventQueue<u32> = EventQueue::new(3);
        q.push_scoped(ms(1), 1, 2, 10);
        q.purge_device(1);
        q.push_scoped(ms(2), 1, 2, 11);
        q.purge_device(1);
        q.push_scoped(ms(3), 1, 2, 12);
        assert_eq!(q.pop(), Some((ms(3), 12)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn depth_counts_only_live_scoped_entries() {
        let mut q: EventQueue<u8> = EventQueue::new(3);
        q.push_scoped(ms(1), 0, 1, 0);
        q.push_scoped(ms(2), 0, 1, 0);
        q.push_scoped(ms(3), 1, 2, 0);
        q.push(ms(4), 0); // unscoped: not a delivery, not counted
        assert_eq!(q.depth_by_device(), vec![0, 2, 1]);
        q.purge_device(0);
        assert_eq!(q.depth_by_device(), vec![0, 0, 1]);
        assert_eq!(q.len(), 4, "tombstones stay in the heap until popped over");
    }
}
