//! Deterministic scenario simulation (DESIGN.md §7).
//!
//! The paper's headline claims are about behavior under failure:
//! multi-device failures mid-training, recovery via chain/central weight
//! replication (§III-D/F), and dynamic re-partition under time-varying
//! compute. This module makes those paths *testable in CI*: a virtual
//! [`clock::Clock`], a synthetic natively-executable model
//! ([`fixture`]), a declarative failure-scenario script ([`script`]),
//! and a single-threaded discrete-event runner ([`runner`]) that drives
//! the full `StageWorker` protocol stack — injection, 1F1B, replication,
//! fault detection, probing, Algorithm-1 redistribution, commit/reset —
//! over a bandwidth/latency-modeled virtual network.
//!
//! Two invocations of the same scenario produce **byte-identical event
//! traces and bit-identical final weights**: everything runs on one
//! thread, every queue is ordered, and all time comes from the virtual
//! clock. The scenario suite lives in `rust/tests/scenarios/`.

pub mod clock;
pub mod fixture;
pub mod runner;
pub mod script;

pub use clock::{real_clock, Clock, RealClock, SharedClock, VirtualClock};
pub use runner::{run_scenario, RedistRecord, ScenarioOutcome};
pub use script::{Action, Scenario, ScriptEvent, Trigger};
