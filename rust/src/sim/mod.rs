//! Deterministic scenario simulation (DESIGN.md §7).
//!
//! The paper's headline claims are about behavior under failure:
//! multi-device failures mid-training, recovery via chain/central weight
//! replication (§III-D/F), and dynamic re-partition under time-varying
//! compute. This module makes those paths *testable in CI*: a virtual
//! [`clock::Clock`], a synthetic natively-executable model
//! ([`fixture`]), a declarative failure-scenario script ([`script`]),
//! and a single-threaded discrete-event runner ([`runner`]) that drives
//! the full `StageWorker` protocol stack — injection, 1F1B, replication,
//! fault detection, probing, Algorithm-1 redistribution, commit/reset —
//! over a bandwidth/latency-modeled virtual network.
//!
//! Two invocations of the same scenario produce **byte-identical event
//! traces and bit-identical final weights**: everything runs on one
//! thread, every queue is ordered ([`queue`] — the `(time, seq)`-keyed
//! min-heap engine, DESIGN.md §11), and all time comes from the virtual
//! clock. The scenario suite lives in `rust/tests/scenarios/`.

use std::time::Duration;

pub mod clock;
pub mod fixture;
pub mod queue;
pub mod replica;
pub mod runner;
pub mod script;

pub use clock::{real_clock, Clock, RealClock, SharedClock, VirtualClock};
pub use replica::{run_replica_scenario, SyncRecord};
pub use runner::{run_scenario, RedistRecord, ScenarioOutcome};
pub use script::{
    chaos_events, hetero_capacities, hetero_link_topology, rolling_churn_events,
    straggler_events, Action, Scenario, ScriptEvent, Trigger,
};

/// The big-cluster chaos storm: `n` devices with 10x-heterogeneous
/// capacities over an asymmetric per-link bandwidth topology
/// (20–200 MB/s), shaken by rolling churn waves whose kills all revive
/// far inside the fault timeout (case-2 by construction, so the fleet
/// never shrinks and the schedule is recoverable at any width). The
/// canonical instance is `big_cluster_storm(500, 10, 7)` — the scenario
/// the `scale` family and the `storm_500dev_wall_s` bench row both run.
///
/// Tuning notes, load-bearing for "simulates in seconds":
/// * `ns_per_flop` 0.05 + 20 µs latency keep virtual stage times small
///   so a batch crosses `n` stages in bounded virtual time;
/// * `fault_timeout` 30 s ≫ the 10–60 ms revives, so churn stays in the
///   cheap case-2 lane instead of the `O(B·S²)` partition DP;
/// * `probe_window` 1 s bounds each probe round at `n` acks.
///
/// Pair with [`fixture::FixtureSpec`] `{ n_blocks: n + 12, dim: 8,
/// classes: 4, batch: 4, seed: 11 }` so every device owns at least one
/// block (the scale tests and the bench share that fixture).
pub fn big_cluster_storm(n: usize, batches: u64, seed: u64) -> Scenario {
    let mut sc = Scenario::exact_recovery("big-cluster-storm", n, batches);
    sc.capacities = hetero_capacities(n, 10.0, seed);
    sc.seed = seed;
    sc.ns_per_flop = 0.05;
    sc.latency = Duration::from_micros(20);
    sc.bandwidth_bps = 1e8;
    sc.fault_timeout = Duration::from_secs(30);
    sc.probe_window = Duration::from_secs(1);
    sc.redist_window = Duration::from_secs(60);
    sc.with_link_bw(hetero_link_topology(n, 2e7, 2e8, seed))
        .with_events(rolling_churn_events(n, batches, 3, 4, seed))
}
