//! Deterministic discrete-event scenario runner.
//!
//! Drives the full `StageWorker` protocol stack — injection, async 1F1B,
//! weight stashing/aggregation, chain+global replication, fault
//! detection, probing, Algorithm-1 redistribution, commit/reset — for
//! every device of a simulated cluster **on one thread over a virtual
//! timeline**. The network is the same cost model as `net::sim::SimNet`
//! (per-directed-link serialization, `latency + bytes/bandwidth`), but
//! time is the scenario's [`VirtualClock`] instead of wall sleeps, and
//! compute is priced from manifest flop counts instead of measured — so
//! two invocations of one scenario produce byte-identical event traces
//! and bit-identical final weights.
//!
//! The event engine (DESIGN.md §11) is sized for fleets in the hundreds
//! of devices: a binary min-heap keyed on `(virtual time, seq)`
//! ([`crate::sim::queue::EventQueue`]) instead of a `BTreeMap`, all
//! scheduling and pricing state owned directly by the single-threaded
//! runner, and a thin [`Outbox`] as the only shared surface worker code
//! sends through — drained back into the priced queue before any state
//! the sends were made under can change, which is what keeps traces
//! byte-identical to the old locked design. Killing the central node
//! purges its in-flight traffic with a per-device generation bump
//! (tombstoned deliveries skip on pop) instead of rebuilding the queue.
//!
//! The coordinator phase logic IS `coordinator::core` — the runner holds
//! a [`PhaseMachine`] and executes the [`PhaseEffect`]s it returns
//! against the virtual fabric, instead of blocking loops (or a private
//! phase enum of its own — DESIGN.md §12), with one
//! deliberate extension: a redistribution that stalls past
//! `Scenario::redist_window` re-enters fault handling (re-probe, replan
//! with the enlarged failure set) instead of aborting the run — that is
//! what makes "a worker dies during an in-flight redistribution"
//! a *recoverable* scripted scenario.
//!
//! Central-node failure (paper §III-E) is a scriptable event like any
//! worker kill: `Scenario::checkpoint_every` writes periodic checkpoints
//! into an in-memory [`MemorySink`], [`Action::KillCentral`] wipes every
//! piece of coordinator memory and drops device 0's traffic (including
//! bytes in flight — the dead process's sockets are gone), and
//! [`Action::RestartCentral`] reboots from the newest checkpoint: it
//! re-announces with `CentralRestart`, collects `WorkerState` replies,
//! warm-starts every surviving stage from the checkpointed weights
//! (always f32 — restore is a correctness path, never quantized), and
//! resumes injection from the checkpoint's committed batch + 1. Workers
//! missing from the handshake are handled exactly like a case-3 fault
//! against the checkpoint topology, which is what makes a combined
//! central+worker storm — or a central death mid-redistribution —
//! recoverable. See DESIGN.md §9.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::checkpoint::{Checkpoint, CheckpointSink, CheckpointState, MemorySink};
use crate::config::DeviceConfig;
use crate::coordinator::core::{
    prune_link_state, CoordinatorPhase, PhaseConfig, PhaseEffect, PhaseInput, PhaseMachine,
    RedistReason, WorkerRoster,
};
use crate::data::SynthVision;
use crate::device::SimDevice;
use crate::fault::{renumber_worker_list, FaultDetector};
use crate::manifest::Manifest;
use crate::model::BlockParams;
use crate::net::message::{DeviceId, Message, ReplicaKind, TrainInit};
use crate::net::quant::{AdaptivePolicy, Compression};
use crate::net::Transport;
use crate::partition::{homogeneous_partition, optimal_partition, CostModel, Partition};
use crate::pipeline::{CompletedBatch, ControlEvent, DataEvent, Event, StageWorker, StepKind};
use crate::profile::{CapacityEstimator, ModelProfile};
use crate::replication;
use crate::runtime::{load_all_blocks_native, HostTensor};
use crate::sim::clock::{SharedClock, VirtualClock};
use crate::sim::queue::EventQueue;
use crate::sim::script::{Action, Scenario, Trigger};

const MAX_RECOVERIES: usize = 50;

/// Safety valve against scripted livelocks, derived from the scenario's
/// actual size (a fixed constant was either uselessly huge for a
/// 3-device family or a false deadlock for a 500-device storm). Budget:
/// a batch costs O(n) deliver/wake/compute events per pipeline pass
/// plus replication to neighbors and the central node; every recovery
/// round (bounded by `MAX_RECOVERIES`) and scripted event adds probe,
/// redistribution and fetch traffic that also grows with fleet width.
/// The constants are deliberate overshoot — the ceiling exists to name
/// a livelock, not to meter healthy runs.
fn event_ceiling(sc: &Scenario) -> u64 {
    let n = sc.n_devices() as u64;
    let per_batch = 96 * (n + 8);
    let rounds = sc.events.len() as u64 + MAX_RECOVERIES as u64 + 1;
    let fault_budget = 4096 * rounds * (n / 16 + 1);
    1_000_000 + sc.batches.saturating_mul(per_batch).saturating_add(fault_budget)
}

// ---------------------------------------------------------------------
// virtual network
// ---------------------------------------------------------------------

enum QueuedEv {
    Deliver { from: DeviceId, to: DeviceId, msg: Message },
    Wake { dev: DeviceId },
    Script { idx: usize },
    Revive { dev: DeviceId },
    /// Scheduled reboot of the central node (KillCentral::restart_after).
    RestartCentral,
}

/// Runner-owned scheduling and pricing state of the virtual fabric.
/// Nothing here is behind a lock: the runner is single-threaded, and
/// worker code only ever reaches the fabric through [`Outbox`].
struct VirtualNet {
    latency: Duration,
    /// Cluster-default link bandwidth ([`Action::SetBandwidth`]
    /// retargets it; per-link overrides are untouched).
    bw_bps: f64,
    /// Per-directed-link bandwidth overrides (`Scenario::link_bw` plus
    /// [`Action::SetLinkBandwidth`]). Lookup-only by exact key — never
    /// iterated — so the unordered map cannot leak nondeterminism.
    link_bw: HashMap<(DeviceId, DeviceId), f64>,
    /// Per-device virtual time used to timestamp its sends (the runner
    /// sets it to the device's compute-completion time before a step).
    local_now: Vec<Duration>,
    /// Directed link -> time it finishes its current transfer.
    /// Lookup-only, like `link_bw`.
    link_free: HashMap<(DeviceId, DeviceId), Duration>,
    dead: Vec<bool>,
    queue: EventQueue<QueuedEv>,
    bytes_total: u64,
    /// When Some(i), FetchWeights sends are recorded for redistribution i.
    recording: Option<usize>,
    fetch_log: Vec<(usize, DeviceId, DeviceId, Vec<usize>)>,
}

impl VirtualNet {
    fn bw(&self, from: DeviceId, to: DeviceId) -> f64 {
        self.link_bw.get(&(from, to)).copied().unwrap_or(self.bw_bps)
    }

    fn send_from(&mut self, from: DeviceId, to: DeviceId, msg: Message) {
        if self.dead[from] || self.dead[to] {
            return; // dropped silently, like a crashed peer
        }
        let bytes = msg.byte_len() as u64;
        self.bytes_total += bytes;
        if let (Some(idx), Message::FetchWeights { blocks }) = (self.recording, &msg) {
            self.fetch_log.push((idx, from, to, blocks.clone()));
        }
        let depart = self.local_now[from];
        let free = self.link_free.get(&(from, to)).copied().unwrap_or(Duration::ZERO);
        let transfer = Duration::from_secs_f64(bytes as f64 / self.bw(from, to));
        let arrive = depart.max(free) + self.latency + transfer;
        self.link_free.insert((from, to), arrive);
        self.queue.push_scoped(arrive, from, to, QueuedEv::Deliver { from, to, msg });
    }
}

/// The thin shared send surface: worker sends append here and the
/// runner drains them into the priced queue ([`Runner::drain_sends`])
/// before any scheduling state they were made under can change. The
/// `Mutex` exists only because [`Transport`] is `Send`; it is
/// uncontended and touched once per send plus once per drain — not
/// once per event like the old whole-network lock.
struct Outbox {
    n: usize,
    pending: Mutex<Vec<(DeviceId, DeviceId, Message)>>,
}

/// One device's `Transport` into the virtual fabric. `recv_timeout`
/// never blocks — the runner delivers messages by driving handlers
/// directly, which is what makes the event order total and replayable.
#[derive(Clone)]
struct NetHandle {
    id: DeviceId,
    out: Arc<Outbox>,
}

impl Transport for NetHandle {
    fn my_id(&self) -> DeviceId {
        self.id
    }

    fn send(&self, to: DeviceId, msg: Message) -> Result<()> {
        self.out.pending.lock().unwrap().push((self.id, to, msg));
        Ok(())
    }

    fn recv_timeout(&self, _timeout: Duration) -> Option<(DeviceId, Message)> {
        None
    }

    fn n_devices(&self) -> usize {
        self.out.n
    }
}

// ---------------------------------------------------------------------
// outcome
// ---------------------------------------------------------------------

/// One redistribution as observed by the runner (fetch counts are
/// asserted against [`crate::fault::plan_redistribution`] in the tests).
#[derive(Debug, Clone)]
pub struct RedistRecord {
    pub reason: String,
    /// Failed stage indices in the OLD worker list (empty for dynamic).
    pub failed: Vec<usize>,
    pub old_ranges: Partition,
    pub new_ranges: Partition,
    pub old_list: Vec<DeviceId>,
    pub new_list: Vec<DeviceId>,
    /// Every FetchWeights sent during this redistribution:
    /// (requester, target, blocks).
    pub fetches: Vec<(DeviceId, DeviceId, Vec<usize>)>,
    pub committed_at_start: i64,
}

/// Everything a scenario run produces.
pub struct ScenarioOutcome {
    /// Deterministic event trace — byte-identical across runs of the
    /// same scenario (losses are logged as f32 bit patterns).
    pub trace: Vec<String>,
    /// Final loss per batch id (a replayed batch overwrites its entry).
    pub losses: BTreeMap<u64, f32>,
    /// Final parameters of every block, gathered from the live devices.
    pub final_weights: BTreeMap<usize, BlockParams>,
    pub redists: Vec<RedistRecord>,
    /// Fault-handler activations (probe rounds).
    pub recoveries: usize,
    /// Checkpoints written to the in-memory sink.
    pub checkpoints: usize,
    /// Central-node reboots taken from the sink.
    pub restarts: usize,
    pub virtual_ms: f64,
    pub net_bytes: u64,
    /// Events the engine processed (tombstones excluded) — the
    /// numerator of the `sim_events_per_sec` bench metric.
    pub events: u64,
    /// [`PhaseMachine`] transition log (kind-only, deterministic): the
    /// cross-driver conformance test compares its recovery suffix with
    /// the threaded coordinator's.
    pub phase_log: Vec<String>,
    /// One record per resolved cross-replica sync round (empty for
    /// R = 1): pre-averaging weights per chain and the averaged result,
    /// exactly as the central fold saw them (DESIGN.md §14).
    pub sync_records: Vec<crate::sim::replica::SyncRecord>,
}

impl ScenarioOutcome {
    /// Bit-exact weight comparison (NaN-safe: compares representations).
    pub fn weights_bits(&self) -> Vec<(usize, Vec<Vec<u32>>)> {
        self.final_weights
            .iter()
            .map(|(&b, bp)| {
                (b, bp.0.iter().map(|t| t.iter().map(|x| x.to_bits()).collect()).collect())
            })
            .collect()
    }
}

// ---------------------------------------------------------------------
// the runner
// ---------------------------------------------------------------------

/// Run `scenario` against the (native) model at `model_dir`.
pub fn run_scenario(scenario: &Scenario, model_dir: &Path) -> Result<ScenarioOutcome> {
    scenario.validate()?;
    if scenario.replicas > 1 {
        // The replica runner owns the hybrid-parallel world; R = 1 stays
        // on this runner untouched, which is what keeps every
        // pre-existing trace byte-identical (DESIGN.md §14).
        return crate::sim::replica::run_replica_scenario(scenario, model_dir);
    }
    let manifest = Arc::new(Manifest::load(model_dir)?);
    let n = scenario.n_devices();
    if manifest.n_blocks() < n {
        bail!("{} blocks < {} devices", manifest.n_blocks(), n);
    }
    let clock = VirtualClock::shared();
    let shared: SharedClock = clock.clone();
    let vnet = VirtualNet {
        latency: scenario.latency,
        bw_bps: scenario.bandwidth_bps,
        link_bw: scenario.link_bw.iter().map(|&(f, t, b)| ((f, t), b)).collect(),
        local_now: vec![Duration::ZERO; n],
        link_free: HashMap::new(),
        dead: vec![false; n],
        queue: EventQueue::with_capacity(n, 4 * n + 64),
        bytes_total: 0,
        recording: None,
        fetch_log: Vec::new(),
    };
    let out = Arc::new(Outbox { n, pending: Mutex::new(Vec::with_capacity(32)) });
    let handles: Vec<NetHandle> = (0..n).map(|id| NetHandle { id, out: out.clone() }).collect();
    let mut workers = Vec::with_capacity(n);
    for d in 0..n {
        let blocks = load_all_blocks_native(&manifest)?;
        let cfg = DeviceConfig { capacity: scenario.capacities[d], ..DeviceConfig::default() };
        let sim = SimDevice::with_clock(
            cfg,
            scenario.seed ^ (d as u64).wrapping_mul(0x9E3779B9),
            shared.clone(),
            Some(scenario.ns_per_flop),
        );
        let mut w = StageWorker::new(d, manifest.clone(), blocks, sim, None);
        w.set_clock(shared.clone());
        workers.push(w);
    }
    let dim: usize = manifest.input_shape.iter().skip(1).product();
    let classes = manifest.n_classes.context("fixture manifest missing n_classes")?;
    let trace_cap = (scenario.batches as usize).saturating_mul(3) + scenario.events.len() * 2 + 64;
    let runner = Runner {
        sc: scenario,
        manifest: manifest.clone(),
        clock,
        vnet,
        out,
        drain_buf: Vec::with_capacity(32),
        handles,
        busy_until: vec![Duration::ZERO; n],
        inbox: (0..n).map(|_| VecDeque::with_capacity(8)).collect(),
        dead: vec![false; n],
        workers,
        data: SynthVision::new(dim, classes, 0.5, scenario.seed, 0),
        profile: ModelProfile::from_flops(&manifest, scenario.ns_per_flop),
        estimator: CapacityEstimator::default(),
        detector: FaultDetector::with_clock(scenario.fault_timeout, shared),
        measured_bw: BTreeMap::new(),
        adaptive: (scenario.compression == Compression::Adaptive)
            .then(|| AdaptivePolicy::new(scenario.adaptive.clone())),
        machine: PhaseMachine::new(PhaseConfig {
            probe_window: scenario.probe_window,
            redist_window: scenario.redist_window,
        }),
        roster: WorkerRoster::unlimited(),
        next_inject: 0,
        inflight: 0,
        completed: -1,
        total: scenario.batches,
        next_repart: scenario.repartition.map(|(first, _)| first),
        losses: BTreeMap::new(),
        trace: Vec::with_capacity(trace_cap),
        redists: Vec::new(),
        recoveries: 0,
        fired: vec![false; scenario.events.len()],
        redist_count: 0,
        events_processed: 0,
        event_ceiling: event_ceiling(scenario),
        sink: MemorySink::default(),
        ckpt_restore: None,
        checkpoints: 0,
        restarts: 0,
        last_checkpoint: 0,
    };
    runner.run()
}

struct Runner<'a> {
    sc: &'a Scenario,
    manifest: Arc<Manifest>,
    clock: Arc<VirtualClock>,
    vnet: VirtualNet,
    out: Arc<Outbox>,
    /// Reused drain buffer — swapped with the outbox so the hot path
    /// never allocates.
    drain_buf: Vec<(DeviceId, DeviceId, Message)>,
    handles: Vec<NetHandle>,
    busy_until: Vec<Duration>,
    inbox: Vec<VecDeque<(DeviceId, Message)>>,
    dead: Vec<bool>,
    workers: Vec<StageWorker>,
    data: SynthVision,
    profile: ModelProfile,
    estimator: CapacityEstimator,
    detector: FaultDetector,
    /// Per-link bandwidth from BwReports, keyed by destination device.
    /// Pruned on every worker-list change (`core::prune_link_state`);
    /// coordinator memory, so a central kill resets it.
    measured_bw: BTreeMap<DeviceId, f64>,
    /// Per-link tier controller for `Compression::Adaptive` (None
    /// otherwise) — coordinator memory, so a central kill resets it.
    adaptive: Option<AdaptivePolicy>,
    /// The shared coordinator phase machine (`coordinator::core`): all
    /// phase decisions happen in its `step`; the runner only executes
    /// the effects against the virtual fabric.
    machine: PhaseMachine,
    /// Worker admission (coordinator memory — a central kill resets it).
    roster: WorkerRoster,
    next_inject: u64,
    inflight: usize,
    completed: i64,
    total: u64,
    next_repart: Option<u64>,
    losses: BTreeMap<u64, f32>,
    trace: Vec<String>,
    redists: Vec<RedistRecord>,
    recoveries: usize,
    fired: Vec<bool>,
    redist_count: usize,
    events_processed: u64,
    event_ceiling: u64,
    /// In-memory checkpoint store (the harness's §III-E "disk").
    sink: MemorySink,
    /// Checkpoint being restored, carried from restart to finish_rejoin.
    ckpt_restore: Option<Checkpoint>,
    checkpoints: usize,
    restarts: usize,
    last_checkpoint: u64,
}

impl Runner<'_> {
    // -------------------------------------------------- infrastructure

    fn trace_line(&mut self, at: Duration, args: std::fmt::Arguments<'_>) {
        use std::fmt::Write as _;
        let mut line = String::with_capacity(64);
        let _ = write!(line, "[{:>13}ns] {}", at.as_nanos(), args);
        self.trace.push(line);
    }

    /// Price every message worker code pushed through the send surface
    /// and move it into the event queue, in push order.
    ///
    /// INVARIANT (the byte-identity argument, DESIGN.md §11): a send is
    /// priced with the `local_now`/`link_free`/`dead`/`recording` state
    /// it was made under, and its queue `seq` must precede any event the
    /// runner pushes afterwards. Both hold because every mutation point
    /// of that state — and every queue push — drains first: `set_local`,
    /// `wake`, `schedule`, `pop_event`, dead-bit flips (kill / revive /
    /// kill_central / restart_central), bandwidth retargets, and
    /// `recording` clears all begin with a drain, and nothing between a
    /// worker call and the next such point touches pricing state.
    fn drain_sends(&mut self) {
        let mut buf = std::mem::take(&mut self.drain_buf);
        std::mem::swap(&mut buf, &mut *self.out.pending.lock().unwrap());
        for (from, to, msg) in buf.drain(..) {
            self.vnet.send_from(from, to, msg);
        }
        self.drain_buf = buf;
    }

    fn set_local(&mut self, d: DeviceId, t: Duration) {
        self.drain_sends(); // pending sends were priced under the old local_now
        self.vnet.local_now[d] = t;
    }

    fn wake(&mut self, d: DeviceId, at: Duration) {
        self.drain_sends(); // pending sends precede this push in seq order
        self.vnet.queue.push(at, QueuedEv::Wake { dev: d });
    }

    fn schedule(&mut self, at: Duration, ev: QueuedEv) {
        self.drain_sends();
        self.vnet.queue.push(at, ev);
    }

    fn pop_event(&mut self) -> Option<(Duration, QueuedEv)> {
        self.drain_sends();
        self.vnet.queue.pop()
    }

    fn peers_of_central(&self) -> Vec<DeviceId> {
        self.workers[0].worker_list.iter().copied().filter(|&d| d != 0).collect()
    }

    // -------------------------------------------------- top level

    fn run(mut self) -> Result<ScenarioOutcome> {
        self.bootstrap()?;
        loop {
            if self.completed + 1 >= self.total as i64
                && self.inflight == 0
                && self.machine.phase() == CoordinatorPhase::Training
            {
                break;
            }
            let Some((at, ev)) = self.pop_event() else {
                bail!(
                    "scenario {:?} deadlocked: event queue empty at batch {}/{} (phase lost)",
                    self.sc.name,
                    self.completed + 1,
                    self.total
                );
            };
            self.events_processed += 1;
            if self.events_processed > self.event_ceiling {
                let mut busiest: Vec<(DeviceId, usize)> = self
                    .vnet
                    .queue
                    .depth_by_device()
                    .into_iter()
                    .enumerate()
                    .filter(|&(_, depth)| depth > 0)
                    .collect();
                busiest.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                busiest.truncate(4);
                bail!(
                    "scenario {:?} exceeded its derived event ceiling {} \
                     (n_devices={}, batches={}, scripted events={}): phase {}, \
                     batch {}/{}, inflight {}, busiest in-flight links by \
                     destination (device, depth): {busiest:?}",
                    self.sc.name,
                    self.event_ceiling,
                    self.sc.n_devices(),
                    self.total,
                    self.sc.events.len(),
                    self.machine.phase(),
                    self.completed + 1,
                    self.total,
                    self.inflight,
                );
            }
            self.clock.set(at);
            match ev {
                QueuedEv::Deliver { from, to, msg } => {
                    // re-check at delivery: either endpoint may have died
                    // while the message was in flight
                    if !self.vnet.dead[from] && !self.vnet.dead[to] {
                        self.inbox[to].push_back((from, msg));
                        self.wake(to, at);
                    }
                }
                QueuedEv::Wake { dev } => self.drive(dev, at)?,
                QueuedEv::Script { idx } => self.fire_action(idx, at)?,
                QueuedEv::RestartCentral => self.restart_central(at)?,
                QueuedEv::Revive { dev } => {
                    self.drain_sends(); // sends to a dead device must still drop
                    self.dead[dev] = false;
                    self.vnet.dead[dev] = false;
                    self.busy_until[dev] = at;
                    self.trace_line(at, format_args!("script: revive device {dev}"));
                }
            }
        }
        self.finish()
    }

    fn finish(mut self) -> Result<ScenarioOutcome> {
        // price any sends still pending from the final event so the
        // byte accounting matches the old send-time-priced design
        self.drain_sends();
        let end = self.clock.now();
        self.trace_line(end, format_args!("run complete"));
        // gather final weights straight from the surviving devices
        let mut final_weights: BTreeMap<usize, BlockParams> = BTreeMap::new();
        for &dev in &self.workers[0].worker_list.clone() {
            for (&b, bp) in &self.workers[dev].params.blocks {
                final_weights.insert(b, bp.clone());
            }
        }
        if final_weights.len() != self.manifest.n_blocks() {
            bail!(
                "final pipeline covers {}/{} blocks",
                final_weights.len(),
                self.manifest.n_blocks()
            );
        }
        // attach the recorded fetches to their redistributions
        let mut redists = self.redists;
        for (idx, from, to, blocks) in std::mem::take(&mut self.vnet.fetch_log) {
            if let Some(r) = redists.get_mut(idx) {
                r.fetches.push((from, to, blocks));
            }
        }
        Ok(ScenarioOutcome {
            trace: self.trace,
            losses: self.losses,
            final_weights,
            redists,
            recoveries: self.recoveries,
            checkpoints: self.checkpoints,
            restarts: self.restarts,
            virtual_ms: end.as_secs_f64() * 1e3,
            net_bytes: self.vnet.bytes_total,
            events: self.events_processed,
            phase_log: self.machine.take_log(),
            sync_records: Vec::new(),
        })
    }

    // -------------------------------------------------- bootstrap

    fn train_init(&self, ranges: Partition, worker_list: Vec<DeviceId>, status: u8) -> TrainInit {
        TrainInit {
            committed_forward: -1,
            committed_backward: -1,
            lr: self.sc.lr,
            momentum: self.sc.momentum,
            weight_decay: self.sc.weight_decay,
            epochs: 1,
            batches_per_epoch: self.total,
            ranges,
            worker_list,
            agg_k: self.sc.agg_k,
            chain_every: self.sc.chain_every,
            global_every: self.sc.global_every,
            status,
            compression: self.sc.compression,
            bw_probe_every: self.sc.bw_probe_every,
            bw_probe_bytes: self.sc.bw_probe_bytes,
            tier_floor: self.sc.adaptive.tier_floor,
            tier_ceiling: self.sc.adaptive.tier_ceiling,
            replica_epoch: self.restarts as u64,
            worker_quota: self.roster.quota_wire(),
            replicas: self.sc.replicas as u64,
            sync_every: self.sc.sync_every,
        }
    }

    /// The capacity-blind cost model behind the very first partition —
    /// shared by [`Self::bootstrap`] and the empty-sink restart fallback
    /// ([`Self::initial_checkpoint`]).
    fn init_cost_model(&self) -> CostModel {
        let n = self.sc.n_devices();
        CostModel {
            t0_ms: self.profile.t0_ms.clone(),
            out_bytes: self.profile.out_bytes.clone(),
            capacities: vec![1.0; n],
            bandwidth_bps: (0..n - 1).map(|l| self.sc.link_bw_for(l, l + 1)).collect(),
        }
    }

    fn bootstrap(&mut self) -> Result<()> {
        let n = self.sc.n_devices();
        let (init_ranges, _) = homogeneous_partition(&self.init_cost_model());
        let worker_list: Vec<DeviceId> = (0..n).collect();
        let ti = self.train_init(init_ranges.clone(), worker_list, 0);
        let h = self.handles[0].clone();
        self.set_local(0, Duration::ZERO);
        for d in 1..n {
            h.send(d, Message::InitState(ti.clone()))?;
        }
        self.workers[0].apply_init(&ti)?;
        self.workers[0].measure_bandwidth(&h)?;
        for d in 1..n {
            self.roster.admit(d)?;
        }
        // the sim skips profiling (compute is priced from flop counts),
        // so the machine goes straight Idle -> Training
        self.machine.step(PhaseInput::TrainingStarted)?;
        self.trace_line(Duration::ZERO, format_args!("init partition {init_ranges:?}"));
        for (idx, ev) in self.sc.events.iter().enumerate() {
            if let Trigger::At(t) = ev.at {
                self.schedule(t, QueuedEv::Script { idx });
            }
        }
        self.wake(0, Duration::from_nanos(1));
        Ok(())
    }

    // -------------------------------------------------- device driving

    fn drive(&mut self, d: DeviceId, t: Duration) -> Result<()> {
        if self.dead[d] {
            self.inbox[d].clear();
            return Ok(());
        }
        if t < self.busy_until[d] {
            let at = self.busy_until[d];
            self.wake(d, at);
            return Ok(());
        }
        self.set_local(d, t);
        let h = self.handles[d].clone();
        while let Some((from, msg)) = self.inbox[d].pop_front() {
            if d == 0 {
                self.central_message(from, msg)?;
            } else {
                self.workers[d].handle_message(&h, from, msg)?;
            }
        }
        if d == 0 {
            self.central_checks(t)?;
            // 1F1B at the coordinator: a queued backward beats injection
            let prefer_bwd = matches!(
                self.workers[0].next_step_kind(),
                Some(StepKind::Backward { .. })
            );
            if !prefer_bwd && self.can_inject() {
                return self.inject(t);
            }
        }
        if let Some(kind) = self.workers[d].next_step_kind() {
            let flops = self.workers[d].step_flops(&kind);
            let cost = self.workers[d]
                .sim
                .modeled_cost(flops)
                .unwrap_or(Duration::from_micros(1));
            let done = t + cost;
            self.busy_until[d] = done;
            self.set_local(d, done);
            let (_ran, cb) = self.workers[d].pump_completed(&h)?;
            if let Some(cb) = cb {
                self.on_complete(cb, done)?;
            }
            self.wake(d, done);
        }
        Ok(())
    }

    fn can_inject(&self) -> bool {
        self.machine.phase() == CoordinatorPhase::Training
            && self.workers[0].initialized
            && self.workers[0].status == 0
            && self.inflight < self.sc.inflight
            && self.next_inject < self.total
    }

    fn inject(&mut self, t: Duration) -> Result<()> {
        let batch = self.next_inject;
        let data = self.data.batch(0, batch, self.manifest.batch_size);
        let h = self.handles[0].clone();
        let last = *self.workers[0].worker_list.last().unwrap();
        self.set_local(0, t);
        let labels = Message::Labels { batch, is_eval: false, data: data.labels.clone() };
        if last == 0 {
            self.workers[0].handle_message(&h, 0, labels)?;
        } else {
            h.send(last, labels)?;
        }
        // price + charge the stage-0 forward
        let kind = StepKind::Forward { batch, is_eval: false };
        let flops = self.workers[0].step_flops(&kind);
        let cost = self.workers[0]
            .sim
            .modeled_cost(flops)
            .unwrap_or(Duration::from_micros(1));
        let done = t + cost;
        self.busy_until[0] = done;
        self.set_local(0, done);
        let version = self.workers[0].version;
        let x = HostTensor::F32(data.x_f32.into());
        self.detector.arm(batch);
        let cb = self.workers[0].forward_train(&h, batch, version, x)?;
        self.trace_line(t, format_args!("inject batch={batch}"));
        self.inflight += 1;
        self.next_inject += 1;
        if let Some(cb) = cb {
            self.on_complete(cb, done)?;
        }
        self.wake(0, done);
        // guarantee the timeout is observed even under total silence
        self.wake(0, t + self.detector.timeout() + Duration::from_millis(1));
        Ok(())
    }

    fn on_complete(&mut self, cb: CompletedBatch, at: Duration) -> Result<()> {
        self.detector.disarm(cb.batch);
        self.inflight = self.inflight.saturating_sub(1);
        self.completed = self.completed.max(cb.batch as i64);
        for r in &cb.reports {
            self.estimator.ingest(r);
        }
        self.trace_line(
            at,
            format_args!("complete batch={} loss_bits={:08x}", cb.batch, cb.loss.to_bits()),
        );
        self.losses.insert(cb.batch, cb.loss);
        // checkpoint BEFORE script triggers: a KillCentral scripted at
        // the same batch mark observes the freshly committed checkpoint
        // (script a non-multiple mark to exercise the stale-replay path)
        self.maybe_checkpoint(at)?;
        self.check_batch_triggers(at)?;
        let repart_due = self.machine.phase() == CoordinatorPhase::Training
            && self.next_repart.is_some_and(|next| self.completed >= next as i64);
        if repart_due {
            let next = self.next_repart.unwrap();
            self.trace_line(at, format_args!("drain for scheduled repartition @{next}"));
            self.machine.step(PhaseInput::DrainForRepartition)?;
        }
        Ok(())
    }

    // -------------------------------------------------- central node

    fn central_message(&mut self, from: DeviceId, msg: Message) -> Result<()> {
        let h = self.handles[0].clone();
        match Event::from_message(from, msg) {
            // recording inputs: the machine absorbs them when they
            // arrive outside their phase (same as the old if-let guards)
            Event::Control(ControlEvent::ProbeAck { id, fresh }) => {
                self.machine.step(PhaseInput::ProbeAck { id, fresh })?;
            }
            Event::Control(ControlEvent::FetchDone { id }) => {
                self.machine.step(PhaseInput::FetchDone { id })?;
            }
            Event::Control(ControlEvent::WorkerState { id, committed_bwd, fresh, .. }) => {
                self.machine.step(PhaseInput::WorkerStateReport { id, committed_bwd, fresh })?;
            }
            Event::Control(ControlEvent::BwReport { stage, bps, to }) => {
                // key by the probed destination device; resolve the
                // reporter's stage against the *live* worker list for
                // pre-v7 reports (to == 0). A report naming a device no
                // longer in the pipeline is stale — drop it instead of
                // resurrecting a pruned link.
                let dest = if to != 0 {
                    to
                } else {
                    self.workers[0].worker_list.get(stage + 1).copied().unwrap_or(0)
                };
                if dest != 0 && self.workers[0].worker_list.contains(&dest) {
                    self.measured_bw.insert(dest, bps);
                    self.maybe_adapt(dest, bps)?;
                }
            }
            ev => {
                // "the central node received the backward gradients of
                // that batch": the timer clears on arrival — the compute
                // step it still has to run must not race the timeout
                if let Event::Data(DataEvent::Backward { batch, .. }) = &ev {
                    if self.workers[0].status == 0 {
                        self.detector.disarm(*batch);
                    }
                }
                self.workers[0].on_event(&h, ev)?;
            }
        }
        Ok(())
    }

    /// Poll the phase machine with the driver's current observations and
    /// execute whatever effects fall out. All phase *decisions* live in
    /// [`PhaseMachine::poll`]; this driver only gathers the inputs.
    fn central_checks(&mut self, t: Duration) -> Result<()> {
        let input = PhaseInput::Poll {
            now: t,
            overdue: self.detector.overdue(),
            inflight: self.inflight,
            peers: self.peers_of_central().len(),
            local_fetch_done: self.workers[0].fetch_done(),
        };
        let (_, effects) = self.machine.step(input)?;
        self.dispatch_effects(effects, t)
    }

    /// Execute [`PhaseEffect`]s against the virtual fabric. The effect
    /// order is the machine's decision order, which matches the old
    /// inline decision table — that is what keeps traces byte-identical.
    fn dispatch_effects(&mut self, effects: Vec<PhaseEffect>, t: Duration) -> Result<()> {
        for eff in effects {
            match eff {
                PhaseEffect::SendProbes { overdue, deadline } => {
                    self.send_probes(overdue, deadline, t)?;
                }
                PhaseEffect::ResolveProbe { acks } => self.finish_probe(acks, t)?,
                PhaseEffect::ResolveRejoin { acks } => self.finish_rejoin(acks, t)?,
                PhaseEffect::CommitRedistribution { expect, reason } => {
                    self.commit_redistribution(expect, reason, t)?;
                }
                PhaseEffect::AbortRedistribution => {
                    self.trace_line(t, format_args!("redistribution stalled; re-probing"));
                    // in-flight fetches of the aborted round were logged
                    // at their (drained) send time, like the old design
                    self.drain_sends();
                    self.vnet.recording = None;
                    // the overdue batch (if any) restarts the fault
                    // handler; otherwise re-probe the committed frontier
                    let b = self
                        .detector
                        .overdue()
                        .unwrap_or((self.completed + 1).max(0) as u64);
                    let (_, eff) =
                        self.machine.step(PhaseInput::FaultDetected { overdue: b, now: t })?;
                    self.dispatch_effects(eff, t)?;
                }
                PhaseEffect::RunDynamicRepartition => self.run_dynamic_repartition(t)?,
                PhaseEffect::BeginSync { .. } | PhaseEffect::ResolveSync { .. } => {
                    // Sync effects exist only in the replica runner's
                    // input vocabulary; this single-chain runner never
                    // feeds SyncDue/SyncPartial, so the machine cannot
                    // emit them here.
                    bail!("single-chain runner received a replica sync effect")
                }
            }
        }
        Ok(())
    }

    /// Feed one link measurement to the per-link adaptive controller; on
    /// a ladder change, trace it, install the new table on the central
    /// stage, and broadcast the full per-link table in `SetCompression`
    /// (DESIGN.md §10). A no-op for static compression policies. Only
    /// the reported destination's ladder can move — a bad link escalates
    /// its own traffic, never the fleet's.
    fn maybe_adapt(&mut self, dest: DeviceId, bps: f64) -> Result<()> {
        let Some(policy) = self.adaptive.as_mut() else {
            return Ok(());
        };
        let old = policy.tier_for(dest);
        let Some(tier) = policy.observe(dest, bps) else {
            return Ok(());
        };
        let floor = policy.thresholds().tier_floor;
        let links = policy.overrides();
        let t = self.clock.now();
        self.trace_line(
            t,
            format_args!(
                "adaptive: link ->{dest} {bps:.0} B/s; tier {} -> {}",
                old.name(),
                tier.name()
            ),
        );
        let h = self.handles[0].clone();
        self.set_local(0, t);
        for d in self.peers_of_central() {
            h.send(d, Message::SetCompression { tier: floor, links: links.clone() })?;
        }
        self.workers[0].apply_compression(floor, &links);
        Ok(())
    }

    /// Execute [`PhaseEffect::SendProbes`]: the machine already moved to
    /// `Probing`; broadcast the probes and schedule the deadline wake.
    fn send_probes(&mut self, overdue: u64, deadline: Duration, t: Duration) -> Result<()> {
        self.recoveries += 1;
        if self.recoveries > MAX_RECOVERIES {
            bail!("scenario {:?}: more than {MAX_RECOVERIES} recoveries", self.sc.name);
        }
        self.trace_line(t, format_args!("fault detected: batch {overdue} overdue; probing"));
        self.workers[0].status = 1;
        let h = self.handles[0].clone();
        self.set_local(0, t);
        for d in self.peers_of_central() {
            h.send(d, Message::Probe)?;
        }
        self.wake(0, deadline + Duration::from_nanos(1));
        Ok(())
    }

    fn finish_probe(&mut self, acks: BTreeMap<DeviceId, bool>, t: Duration) -> Result<()> {
        let worker_list = self.workers[0].worker_list.clone();
        let peers = self.peers_of_central();
        let dead: Vec<DeviceId> =
            peers.iter().copied().filter(|d| !acks.contains_key(d)).collect();
        let fresh: Vec<DeviceId> =
            acks.iter().filter(|(_, &f)| f).map(|(&d, _)| d).collect();
        let committed = self.completed;
        let h = self.handles[0].clone();
        self.set_local(0, t);
        if dead.is_empty() && fresh.is_empty() {
            // CASE 1: everyone healthy — restart from the failed batch
            self.trace_line(
                t,
                format_args!("fault case 1: restart from batch {}", committed + 1),
            );
            self.reset_all(committed, t)?;
        } else if dead.is_empty() {
            // CASE 2: restarted worker(s) — restore from replicas. The
            // fresh workers were never evicted; readmit is idempotent.
            self.trace_line(t, format_args!("fault case 2: restore {fresh:?}"));
            let ranges = self.workers[0].ranges.clone();
            let ti = self.train_init(ranges.clone(), worker_list.clone(), 1);
            for &d in &fresh {
                self.roster.readmit(d)?;
                h.send(d, Message::InitState(ti.clone()))?;
            }
            self.begin_redistribution(
                ranges,
                worker_list,
                vec![],
                RedistReason::Fault,
                "fault case 2",
                t,
            )?;
        } else {
            // CASE 3: dead worker(s) — renumber, re-partition, redistribute
            let failed: Vec<usize> = worker_list
                .iter()
                .enumerate()
                .filter(|(_, d)| dead.contains(d))
                .map(|(s, _)| s)
                .collect();
            self.trace_line(t, format_args!("fault case 3: dead stages {failed:?}"));
            let new_list = renumber_worker_list(&worker_list, &failed);
            let old_ranges = self.workers[0].ranges.clone();
            let alive_old: Vec<(usize, usize)> = old_ranges
                .iter()
                .enumerate()
                .filter(|(s, _)| !failed.contains(s))
                .map(|(_, &r)| r)
                .collect();
            let cm = self.cost_model(&new_list, &alive_old);
            let (new_ranges, _) = optimal_partition(&cm);
            for &d in &dead {
                self.roster.evict(d);
                self.estimator.clear_device(d);
            }
            self.begin_redistribution(
                new_ranges,
                new_list,
                failed,
                RedistReason::Fault,
                "fault case 3",
                t,
            )?;
        }
        Ok(())
    }

    fn begin_redistribution(
        &mut self,
        ranges: Partition,
        list: Vec<DeviceId>,
        failed: Vec<usize>,
        reason: RedistReason,
        label: &str,
        t: Duration,
    ) -> Result<()> {
        let idx = self.redists.len();
        self.redists.push(RedistRecord {
            reason: label.to_string(),
            failed: failed.clone(),
            old_ranges: self.workers[0].ranges.clone(),
            new_ranges: ranges.clone(),
            old_list: self.workers[0].worker_list.clone(),
            new_list: list.clone(),
            fetches: Vec::new(),
            committed_at_start: self.completed,
        });
        self.trace_line(
            t,
            format_args!(
                "redistribution #{} ({label}): {:?} -> {ranges:?}",
                idx + 1,
                self.redists[idx].old_ranges
            ),
        );
        self.vnet.recording = Some(idx);
        let h = self.handles[0].clone();
        self.set_local(0, t);
        let peers: Vec<DeviceId> = list.iter().copied().filter(|&d| d != 0).collect();
        for &d in &peers {
            h.send(
                d,
                Message::Repartition {
                    ranges: ranges.clone(),
                    worker_list: list.clone(),
                    failed: failed.clone(),
                },
            )?;
        }
        self.workers[0].begin_repartition(&h, ranges, list, failed)?;
        let deadline = t + self.sc.redist_window;
        let expect: BTreeSet<DeviceId> = peers.into_iter().collect();
        // a central-only pipeline (every worker dead at restart) has no
        // FetchDone to wait for — without a wake it would idle to the
        // deadline before committing
        if expect.is_empty() {
            self.wake(0, t + Duration::from_nanos(1));
        }
        self.machine.step(PhaseInput::RedistributionStarted { expect, reason, now: t })?;
        self.wake(0, deadline + Duration::from_nanos(1));
        self.redist_count += 1;
        self.check_redist_triggers(t)?;
        Ok(())
    }

    /// Execute [`PhaseEffect::CommitRedistribution`]: the machine is
    /// already back in `Training` and hands over the participant set.
    fn commit_redistribution(
        &mut self,
        expect: BTreeSet<DeviceId>,
        reason: RedistReason,
        t: Duration,
    ) -> Result<()> {
        // flush handler replies made while the fetch log was recording
        self.drain_sends();
        self.vnet.recording = None;
        let h = self.handles[0].clone();
        self.set_local(0, t);
        for &d in &expect {
            h.send(d, Message::Commit)?;
        }
        self.workers[0].apply_commit()?;
        self.trace_line(
            t,
            format_args!(
                "commit: list {:?} ranges {:?}",
                self.workers[0].worker_list, self.workers[0].ranges
            ),
        );
        // the committed list is the live topology now: measurements and
        // tier ladders keyed to departed devices are stale — every
        // worker-list change (repartition, rejoin, eviction) funnels
        // through this one invalidation point
        let traced = self.adaptive.is_some();
        let dropped = prune_link_state(
            &mut self.measured_bw,
            self.adaptive.as_mut(),
            &self.workers[0].worker_list,
        );
        // measurements are dropped either way (the cost model must not
        // price a dead link), but only the adaptive controller narrates —
        // static-policy family traces must not grow new lines
        if traced {
            for d in dropped {
                self.trace_line(t, format_args!("adaptive: link ->{d} invalidated"));
            }
        }
        match reason {
            RedistReason::Fault => self.reset_all(self.completed, t)?,
            RedistReason::Dynamic => self.advance_repart_schedule(),
        }
        self.wake(0, t + Duration::from_nanos(1));
        Ok(())
    }

    fn reset_all(&mut self, committed: i64, t: Duration) -> Result<()> {
        let h = self.handles[0].clone();
        self.set_local(0, t);
        for d in self.peers_of_central() {
            h.send(d, Message::Reset { committed })?;
        }
        // a fresh worker re-inited during this recovery fell back to the
        // policy's floor tier — re-align everyone with the adaptive
        // controller's current per-link table (deterministic: same point
        // in every replay). Nothing to send when every ladder sits at
        // the floor: that is exactly the state a reset worker boots in.
        if let Some(policy) = self.adaptive.as_ref() {
            let links = policy.overrides();
            if !links.is_empty() {
                let floor = policy.thresholds().tier_floor;
                for d in self.peers_of_central() {
                    h.send(d, Message::SetCompression { tier: floor, links: links.clone() })?;
                }
                self.workers[0].apply_compression(floor, &links);
            }
        }
        self.workers[0].apply_reset(committed);
        self.detector.clear();
        self.inflight = 0;
        self.next_inject = (committed + 1) as u64;
        self.trace_line(t, format_args!("reset: resume from batch {}", committed + 1));
        self.wake(0, t + Duration::from_nanos(1));
        Ok(())
    }

    fn advance_repart_schedule(&mut self) {
        self.next_repart = match (self.next_repart, self.sc.repartition) {
            (Some(at), Some((_, every))) if every > 0 => Some(at + every),
            _ => None,
        };
    }

    fn run_dynamic_repartition(&mut self, t: Duration) -> Result<()> {
        let list = self.workers[0].worker_list.clone();
        let old_ranges = self.workers[0].ranges.clone();
        let cm = self.cost_model(&list, &old_ranges);
        let (new_ranges, cost) = optimal_partition(&cm);
        let old_cost = cm.cost(&old_ranges);
        self.trace_line(
            t,
            format_args!(
                "repartition check: caps {:?} -> {new_ranges:?} ({cost:.3}ms)",
                cm.capacities
            ),
        );
        // hysteresis: moving weights has a real cost, so only rebalance
        // for a material (>1%) bottleneck improvement — this also keeps
        // float-epsilon capacity jitter from flipping DP tie-breaks
        // (the machine already landed back in Training, so the no-op arm
        // just advances the schedule)
        if new_ranges == old_ranges || cost > old_cost * 0.99 {
            self.advance_repart_schedule();
            self.wake(0, t + Duration::from_nanos(1));
            return Ok(());
        }
        self.begin_redistribution(new_ranges, list, vec![], RedistReason::Dynamic, "dynamic", t)
    }

    // -------------------------------------------------- central failure
    // (paper §III-E: periodic checkpoint to "disk", recover on restart)

    fn maybe_checkpoint(&mut self, at: Duration) -> Result<()> {
        let every = self.sc.checkpoint_every;
        if every == 0 {
            return Ok(());
        }
        let done = (self.completed + 1) as u64;
        if done == 0 || done % every != 0 || self.last_checkpoint == done {
            return Ok(());
        }
        self.last_checkpoint = done;
        // the snapshot logic is StageWorker::snapshot_checkpoint, shared
        // with the threaded coordinator: in the replicate-every-batch
        // exact regime it is the full committed model
        let ck = self.workers[0].snapshot_checkpoint(self.completed, 0);
        let blocks = ck.weights.len();
        self.sink.save(&ck)?;
        self.checkpoints += 1;
        self.trace_line(
            at,
            format_args!(
                "checkpoint #{} at batch {} ({blocks} blocks)",
                self.checkpoints, self.completed
            ),
        );
        Ok(())
    }

    /// What a reboot with an empty sink restores: the initial weights and
    /// the bootstrap partition, committed = -1 — i.e. the whole run
    /// replays from scratch, which still loses zero committed batches.
    /// Shares [`Self::init_cost_model`] with bootstrap so the replay
    /// provably reboots onto the boot partition.
    fn initial_checkpoint(&self) -> Result<Checkpoint> {
        let n = self.sc.n_devices();
        let (ranges, _) = homogeneous_partition(&self.init_cost_model());
        let mut weights = BTreeMap::new();
        let mut shapes = BTreeMap::new();
        for b in 0..self.manifest.n_blocks() {
            weights.insert(b, BlockParams::from_vecs(self.manifest.load_init_params(b)?));
            shapes.insert(
                b,
                self.manifest.blocks[b].params.iter().map(|p| p.shape.clone()).collect(),
            );
        }
        Ok(Checkpoint {
            state: CheckpointState {
                committed_batch: -1,
                epoch: 0,
                lr: self.sc.lr,
                ranges,
                worker_list: (0..n).collect(),
                shapes,
            },
            weights,
        })
    }

    fn kill_central(&mut self, t: Duration) {
        // KillCentral from Down is the one transition the machine rejects
        // outright — that is exactly the double-kill script guard
        if self.machine.step(PhaseInput::KillCentral).is_err() {
            self.trace_line(t, format_args!("script: kill central ignored (already down)"));
            return;
        }
        // sends made while the central was alive price (and, for
        // FetchWeights, log) under the live fabric — then die with it
        self.drain_sends();
        self.dead[0] = true;
        self.vnet.dead[0] = true;
        self.vnet.recording = None;
        // the process died: bytes in flight to/from its sockets are gone
        // with it — one generation bump tombstones exactly the deliveries
        // touching device 0 (worker kills keep the delivery-time check:
        // their revive semantics predate central restart and existing
        // family traces must not move)
        self.vnet.queue.purge_device(0);
        // all coordinator memory is lost with the process
        self.workers[0].wipe_state();
        self.inbox[0].clear();
        self.detector.clear();
        self.estimator = CapacityEstimator::default();
        self.measured_bw.clear();
        // the tier controller lives in the dead coordinator: it reboots
        // at the policy floor and re-escalates from fresh measurements
        // (workers keep their last-ordered tier until the rejoin
        // InitState resets it — harmless either way, the wire is
        // self-describing)
        if let Some(p) = self.adaptive.as_mut() {
            *p = AdaptivePolicy::new(self.sc.adaptive.clone());
        }
        // the admission roster is coordinator memory too: the restarted
        // process re-admits from the CentralRestart replies
        self.roster = WorkerRoster::unlimited();
        self.inflight = 0;
        self.trace_line(t, format_args!("script: kill central node"));
    }

    fn restart_central(&mut self, t: Duration) -> Result<()> {
        // CentralRestarted only applies in Down: a restart while alive is a
        // script no-op, same as a double kill
        if self.machine.step(PhaseInput::CentralRestarted { now: t }).is_err() {
            self.trace_line(t, format_args!("script: restart central ignored (not down)"));
            return Ok(());
        }
        self.drain_sends(); // nothing may slip past the dead-bit flip
        self.dead[0] = false;
        self.vnet.dead[0] = false;
        self.busy_until[0] = t;
        self.restarts += 1;
        let ck = match self.sink.load_latest()? {
            Some(ck) => ck,
            None => self.initial_checkpoint()?,
        };
        self.trace_line(
            t,
            format_args!(
                "central restart #{}: checkpoint committed={} ({} blocks); probing workers",
                self.restarts,
                ck.state.committed_batch,
                ck.weights.len()
            ),
        );
        // rebuild the central stage from the checkpoint: topology +
        // hyper-parameters via the normal init path (status 1 keeps the
        // manifest's initial weights out), then the stage-0 weights
        let ti = self.train_init(ck.state.ranges.clone(), ck.state.worker_list.clone(), 1);
        self.workers[0].apply_init(&ti)?;
        // re-admit the checkpoint's roster: the kill wiped coordinator
        // memory, so admission restarts from what durable state names
        for d in self.peers_of_central() {
            self.roster.admit(d)?;
        }
        let (lo0, hi0) = ck.state.ranges[0];
        for (&b, bp) in &ck.weights {
            if b >= lo0 && b <= hi0 {
                self.workers[0].params.blocks.insert(b, bp.clone());
            }
        }
        self.completed = ck.state.committed_batch;
        self.next_inject = (self.completed + 1).max(0) as u64;
        self.inflight = 0;
        self.detector.clear();
        // re-announce to every worker the checkpoint knows about; the
        // replies double as the §III-F probe round (a silent worker is a
        // dead worker, reconciled in finish_rejoin)
        let h = self.handles[0].clone();
        self.set_local(0, t);
        for d in self.peers_of_central() {
            h.send(d, Message::CentralRestart { committed: self.completed })?;
        }
        // re-measure the central's own outgoing link, like bootstrap does
        // (workers re-measure theirs when the rejoin InitState lands)
        self.workers[0].measure_bandwidth(&h)?;
        // the machine owns the rejoin ack set; the runner only schedules
        // the deadline wake that will deliver the Poll past it
        let deadline = t + self.sc.probe_window;
        self.ckpt_restore = Some(ck);
        self.wake(0, deadline + Duration::from_nanos(1));
        Ok(())
    }

    /// Reconcile the handshake replies against the checkpoint: roll every
    /// survivor back to the checkpointed weights (uncommitted progress is
    /// discarded — bit-exact replay needs the exact committed state), and
    /// treat silent workers as a case-3 failure of the checkpoint
    /// topology.
    fn finish_rejoin(&mut self, acks: BTreeMap<DeviceId, (i64, bool)>, t: Duration) -> Result<()> {
        let ck = self.ckpt_restore.take().context("finish_rejoin without a restore")?;
        let list = self.workers[0].worker_list.clone();
        let ranges = self.workers[0].ranges.clone();
        let committed = self.completed;
        for (d, (bwd, fresh)) in &acks {
            self.trace_line(
                t,
                format_args!(
                    "rejoin: worker {d} committed_bwd={bwd} fresh={fresh} \
                     (checkpoint committed={committed})"
                ),
            );
        }
        let dead: Vec<DeviceId> = self
            .peers_of_central()
            .into_iter()
            .filter(|d| !acks.contains_key(d))
            .collect();
        let h = self.handles[0].clone();
        self.set_local(0, t);
        // re-seed the central replica store so CentralBackup sources
        // survive the crash (forcibly: a push that raced the handshake
        // carries pre-reset uncommitted state and must not win)
        for (s, &dev) in list.iter().enumerate().skip(1) {
            let (lo, hi) = ranges[s];
            let blocks: Vec<(usize, BlockParams)> =
                (lo..=hi).filter_map(|b| ck.weights.get(&b).map(|bp| (b, bp.clone()))).collect();
            if !blocks.is_empty() {
                self.workers[0].backups.remove_owner(dev);
                // seed at the post-restart epoch so any straggling
                // pre-restart push (a lower epoch) loses the version race
                // (DESIGN.md §9 case 2)
                let v = replication::epoch_version(self.restarts as u64, 0);
                self.workers[0].backups.store(dev, ReplicaKind::Global, s, v, blocks);
            }
        }
        // every rejoined worker is forced onto the checkpoint topology
        // (status 1: weights arrive by push, not from the manifest)...
        let ti = self.train_init(ranges.clone(), list.clone(), 1);
        for &d in acks.keys() {
            h.send(d, Message::InitState(ti.clone()))?;
        }
        // ...then warm-started from the checkpointed weights. Always f32:
        // restore is a correctness path, so it is never quantized even
        // under Compression::Full (DESIGN.md §9).
        for (s, &dev) in list.iter().enumerate().skip(1) {
            if !acks.contains_key(&dev) {
                continue;
            }
            let (lo, hi) = ranges[s];
            let blocks: Vec<crate::net::message::WireBlock> = (lo..=hi)
                .filter_map(|b| ck.weights.get(&b).map(|bp| (b, replication::block_to_wire(bp))))
                .collect();
            if blocks.len() < hi - lo + 1 {
                self.trace_line(
                    t,
                    format_args!(
                        "warning: checkpoint misses blocks of stage {s} (partial replicas)"
                    ),
                );
            }
            if !blocks.is_empty() {
                h.send(dev, Message::Weights { blocks })?;
            }
        }
        if dead.is_empty() {
            self.trace_line(
                t,
                format_args!(
                    "central restart: all workers rejoined; resuming from batch {}",
                    committed + 1
                ),
            );
            self.reset_all(committed, t)?;
        } else {
            // case 3 against the checkpoint topology: renumber, re-plan,
            // redistribute (survivors serve their rolled-back ranges, the
            // re-seeded central backups cover the dead stages)
            let failed: Vec<usize> = list
                .iter()
                .enumerate()
                .filter(|(_, d)| dead.contains(d))
                .map(|(s, _)| s)
                .collect();
            self.trace_line(t, format_args!("central restart: dead stages {failed:?}"));
            let new_list = renumber_worker_list(&list, &failed);
            let alive_old: Vec<(usize, usize)> = ranges
                .iter()
                .enumerate()
                .filter(|(s, _)| !failed.contains(s))
                .map(|(_, &r)| r)
                .collect();
            let cm = self.cost_model(&new_list, &alive_old);
            let (new_ranges, _) = optimal_partition(&cm);
            for &d in &dead {
                self.estimator.clear_device(d);
                self.roster.evict(d);
            }
            self.begin_redistribution(
                new_ranges,
                new_list,
                failed,
                RedistReason::Fault,
                "central restart",
                t,
            )?;
        }
        Ok(())
    }

    fn cost_model(&self, list: &[DeviceId], old_ranges: &[(usize, usize)]) -> CostModel {
        let central_ratio = match (self.workers[0].avg_exec_ms(), self.workers[0].my_range()) {
            (Some(avg), Some((lo, hi))) => {
                let base: f64 = self.profile.t0_ms[lo..=hi].iter().sum();
                if base > 0.0 {
                    avg / base
                } else {
                    1.0
                }
            }
            _ => 1.0,
        };
        // unmeasured links fall back to the scripted topology: the
        // per-link override if one exists, else the scenario's scalar
        // default (NOT the current SetBandwidth value — that keeps the
        // pre-override families byte-identical)
        let bw: Vec<f64> = (0..list.len().saturating_sub(1))
            .map(|l| {
                // pipeline link l feeds the device at slot l+1 of the
                // candidate list — look its measurement up by device id
                let m = self.measured_bw.get(&list[l + 1]).copied().unwrap_or(0.0);
                if m > 0.0 {
                    m
                } else {
                    self.sc.link_bw_for(list[l], list[l + 1])
                }
            })
            .collect();
        let caps =
            self.estimator.capacities(list, old_ranges, &self.profile.t0_ms, central_ratio);
        CostModel {
            t0_ms: self.profile.t0_ms.clone(),
            out_bytes: self.profile.out_bytes.clone(),
            capacities: caps,
            bandwidth_bps: bw,
        }
    }

    // -------------------------------------------------- script events

    fn check_batch_triggers(&mut self, t: Duration) -> Result<()> {
        for idx in 0..self.sc.events.len() {
            if self.fired[idx] {
                continue;
            }
            if let Trigger::BatchDone(b) = self.sc.events[idx].at {
                if self.completed >= b as i64 {
                    self.fire_action(idx, t)?;
                }
            }
        }
        Ok(())
    }

    fn check_redist_triggers(&mut self, t: Duration) -> Result<()> {
        for idx in 0..self.sc.events.len() {
            if self.fired[idx] {
                continue;
            }
            if let Trigger::RedistributionStart(n) = self.sc.events[idx].at {
                if self.redist_count >= n {
                    self.fire_action(idx, t)?;
                }
            }
        }
        Ok(())
    }

    fn fire_action(&mut self, idx: usize, t: Duration) -> Result<()> {
        if self.fired[idx] {
            return Ok(());
        }
        self.fired[idx] = true;
        match self.sc.events[idx].action.clone() {
            Action::Kill { device, revive_after } => {
                self.trace_line(t, format_args!("script: kill device {device}"));
                self.kill(device, t);
                if let Some(delay) = revive_after {
                    self.schedule(t + delay, QueuedEv::Revive { dev: device });
                }
            }
            Action::KillSlice { first, last, revive_after } => {
                self.trace_line(t, format_args!("script: kill slice {first}..={last}"));
                for dev in first..=last {
                    self.kill(dev, t);
                }
                if let Some(delay) = revive_after {
                    for dev in first..=last {
                        self.schedule(t + delay, QueuedEv::Revive { dev });
                    }
                }
            }
            Action::SetCapacity { device, capacity } => {
                self.trace_line(
                    t,
                    format_args!("script: device {device} capacity -> {capacity}"),
                );
                self.workers[device].sim.cfg.capacity = capacity;
            }
            Action::SetBandwidth { bps } => {
                self.trace_line(t, format_args!("script: bandwidth -> {bps} B/s"));
                self.drain_sends(); // pending sends priced at the old rate
                self.vnet.bw_bps = bps;
            }
            Action::SetLinkBandwidth { from, to, bps } => {
                self.trace_line(
                    t,
                    format_args!("script: link {from}->{to} bandwidth -> {bps} B/s"),
                );
                self.drain_sends();
                self.vnet.link_bw.insert((from, to), bps);
            }
            Action::KillCentral { restart_after } => {
                self.kill_central(t);
                if let Some(delay) = restart_after {
                    self.schedule(t + delay, QueuedEv::RestartCentral);
                }
            }
            Action::RestartCentral => self.restart_central(t)?,
            // validate() rejects KillReplica unless replicas > 1, and
            // run_scenario dispatches replicas > 1 to the replica runner
            // before this runner is even built
            Action::KillReplica { .. } => {
                bail!("single-chain runner cannot fire KillReplica")
            }
        }
        Ok(())
    }

    fn kill(&mut self, device: DeviceId, t: Duration) {
        // sends made while the device was alive were priced under the
        // live fabric — flush them before the dead bit flips
        self.drain_sends();
        self.dead[device] = true;
        self.vnet.dead[device] = true;
        self.workers[device].wipe_state();
        self.inbox[device].clear();
        self.busy_until[device] = t;
    }
}
