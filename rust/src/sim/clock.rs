//! The time seam: every timeout, backoff, deadline, and profiling
//! measurement in the system goes through a [`Clock`] so that fault
//! scenarios can run on a **virtual timeline** — scripted, reproducible,
//! and instant — instead of wall time.
//!
//! Two implementations:
//!
//! * [`RealClock`] — monotonic wall time (a process-global epoch), real
//!   sleeps. The default everywhere; production behavior is unchanged.
//! * [`VirtualClock`] — an atomic nanosecond counter advanced explicitly
//!   by the scenario runner ([`crate::sim::runner`]). `sleep` advances
//!   the counter instead of blocking, so code written against the seam
//!   (TCP backoff, the coordinator's pauses) runs instantly and
//!   deterministically under simulation.
//!
//! Times are exchanged as [`Duration`]s since the clock's epoch rather
//! than `std::time::Instant` — `Instant` cannot be fabricated, which is
//! exactly what a virtual timeline needs to do.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// A monotonic time source + sleep facility.
pub trait Clock: Send + Sync + std::fmt::Debug {
    /// Time elapsed since this clock's epoch.
    fn now(&self) -> Duration;

    /// Sleep for `d` (really, or by advancing virtual time).
    fn sleep(&self, d: Duration);
}

/// Shared handle to a clock (cheaply cloneable, thread-safe).
pub type SharedClock = Arc<dyn Clock>;

/// The default clock: wall time against a process-global epoch.
#[derive(Debug, Clone, Copy, Default)]
pub struct RealClock;

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

impl Clock for RealClock {
    fn now(&self) -> Duration {
        epoch().elapsed()
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// A shared [`RealClock`] handle.
pub fn real_clock() -> SharedClock {
    Arc::new(RealClock)
}

/// A scripted timeline: time only moves when the owner advances it.
///
/// `sleep` advances the clock by the requested duration — correct for
/// the single-threaded discrete-event simulation that owns the clock
/// (the sleeper IS the only actor, so its wait defines the new now).
#[derive(Debug, Default)]
pub struct VirtualClock {
    ns: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Shared handle starting at t = 0.
    pub fn shared() -> Arc<VirtualClock> {
        Arc::new(VirtualClock::new())
    }

    /// Move time forward by `d`.
    pub fn advance(&self, d: Duration) {
        self.ns.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }

    /// Jump to an absolute time (must not move backwards).
    pub fn set(&self, t: Duration) {
        let target = t.as_nanos() as u64;
        self.ns.fetch_max(target, Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.ns.load(Ordering::SeqCst))
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_is_monotonic() {
        let c = RealClock;
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_only_moves_when_told() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_millis(250));
        assert_eq!(c.now(), Duration::from_millis(250));
        // no wall time involved: a million virtual seconds are free
        c.advance(Duration::from_secs(1_000_000));
        assert_eq!(c.now(), Duration::from_secs(1_000_000) + Duration::from_millis(250));
    }

    #[test]
    fn virtual_sleep_advances() {
        let c = VirtualClock::new();
        let t0 = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert!(t0.elapsed() < Duration::from_secs(1), "virtual sleep must not block");
        assert_eq!(c.now(), Duration::from_secs(3600));
    }

    #[test]
    fn set_never_rewinds() {
        let c = VirtualClock::new();
        c.set(Duration::from_millis(100));
        c.set(Duration::from_millis(40));
        assert_eq!(c.now(), Duration::from_millis(100));
    }

    #[test]
    fn shared_handle_is_a_clock() {
        let v = VirtualClock::shared();
        let shared: SharedClock = v.clone();
        v.advance(Duration::from_millis(7));
        assert_eq!(shared.now(), Duration::from_millis(7));
    }
}
