//! CI bench-regression gate: diff a bench JSON artifact (emitted by a
//! bench via `FTPIPEHD_BENCH_JSON`, e.g. `micro_runtime`) against the
//! committed `BENCH_BASELINE.json`, failing the job when any gated
//! metric regresses past the baseline's tolerance (default 25%).
//!
//! Usage: `benchcmp <baseline.json> <current.json> [tolerance]`
//!
//! An explicit `[tolerance]` (e.g. `0.5` for 50%) overrides the
//! baseline file's `tolerance` field; without it the baseline's value
//! (default 25%) applies.
//!
//! Gated metrics are machine-portable by construction — byte ratios of
//! the compressed vs f32 wire format and same-process relative timings —
//! so the gate is meaningful on shared CI runners where absolute wall
//! times are noise. The summary is printed to the job log and appended
//! to `$GITHUB_STEP_SUMMARY` when present.

use std::process::ExitCode;

use ftpipehd::util::benchkit::compare_metrics;
use ftpipehd::util::json;

fn load(path: &str) -> Result<json::Value, String> {
    let raw = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    json::parse(&raw).map_err(|e| format!("parsing {path}: {e}"))
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (baseline_path, current_path) = match &args[..] {
        [b, c] | [b, c, _] => (b.clone(), c.clone()),
        _ => {
            eprintln!("usage: benchcmp <baseline.json> <current.json> [tolerance]");
            return ExitCode::from(2);
        }
    };
    // an explicit CLI tolerance must win over the baseline's field; a
    // third argument that does not parse is an error, not 25%
    let tolerance_override: Option<f64> = match args.get(2) {
        None => None,
        Some(t) => match t.parse::<f64>() {
            Ok(x) if x.is_finite() && x >= 0.0 => Some(x),
            _ => {
                eprintln!("benchcmp: bad tolerance {t:?} (want e.g. 0.25)");
                return ExitCode::from(2);
            }
        },
    };

    let (baseline, current) = match (load(&baseline_path), load(&current_path)) {
        (Ok(b), Ok(c)) => (b, c),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("benchcmp: {e}");
            return ExitCode::from(2);
        }
    };

    let deltas = match compare_metrics(&baseline, &current, tolerance_override) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("benchcmp: {e}");
            return ExitCode::from(2);
        }
    };

    let effective_tolerance = tolerance_override
        .or_else(|| baseline.get("tolerance").and_then(|v| v.as_f64()))
        .unwrap_or(0.25);
    let mut lines = vec![format!(
        "## bench-regression gate ({} metrics, tolerance {:.0}%)",
        deltas.len(),
        effective_tolerance * 100.0
    )];
    for d in &deltas {
        lines.push(d.summary());
    }
    let regressed: Vec<&str> =
        deltas.iter().filter(|d| d.regressed).map(|d| d.name.as_str()).collect();
    lines.push(if regressed.is_empty() {
        "result: OK — no metric regressed past tolerance".to_string()
    } else {
        format!("result: FAIL — regressed metrics: {}", regressed.join(", "))
    });
    let summary = lines.join("\n");
    println!("{summary}");
    if let Ok(path) = std::env::var("GITHUB_STEP_SUMMARY") {
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
            let _ = writeln!(f, "```\n{summary}\n```");
        }
    }
    if regressed.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
