//! Microbench: the data-plane hot path — the message codec (f32 vs the
//! INT8-quantized wire format), the quantizer itself, block execution
//! through PJRT (with the literal conversions the pipeline pays per hop),
//! the event-driven TCP transport over loopback, and the discrete-event
//! scenario engine driven flat out by big-cluster storms. These bound the
//! per-batch overhead the coordinator adds on top of raw XLA compute; see
//! EXPERIMENTS.md §Perf.
//!
//! The codec/quantization section is synthetic and always runs — it needs
//! no model artifacts — so CI always gets a real table plus the named
//! `metrics` the bench-regression gate (`benchcmp` vs BENCH_BASELINE.json)
//! diffs. Gate metrics are byte ratios and same-process relative timings,
//! both stable across runner hardware; absolute wall times are reported
//! but not gated.

mod common;

use ftpipehd::manifest::{Dtype, Manifest};
use ftpipehd::net::codec;
use ftpipehd::net::message::{Message, Payload, WireTensor};
use ftpipehd::net::quant::{Bits, ChannelHint};
use ftpipehd::net::{QTensor, TensorBuf};
use ftpipehd::runtime::{load_all_blocks, Engine, HostTensor};
use ftpipehd::util::benchkit::{bench, emit_json_with_metrics, Table};

/// Synthetic activation size: 16K f32 = 64 KiB, a realistic edge hop.
const QN: usize = 16384;

fn ms(x: f64) -> String {
    format!("{:.2} ms", x * 1e3)
}

fn us(x: f64) -> String {
    format!("{:.1} us", x * 1e6)
}

fn quant_codec_section(table: &mut Table, metrics: &mut Vec<(String, f64)>) {
    let xs: Vec<f32> =
        (0..QN).map(|i| ((i as u32).wrapping_mul(2654435761) as f32).sin() * 2.0).collect();
    let act = TensorBuf::from(xs.clone());
    let q = QTensor::quantize(&xs);

    let fwd = |data: Payload| Message::Forward { batch: 1, version0: 1, is_eval: false, data };
    let msg_f32 = fwd(Payload::F32(act.clone()));
    let msg_q8 = fwd(Payload::Quant(q.clone()));
    let frame_f32 = codec::encode(0, &msg_f32);
    let frame_q8 = codec::encode(0, &msg_q8);

    // --- quantizer ---
    let s = bench(5, 500, || {
        let _ = QTensor::quantize(std::hint::black_box(&xs));
    });
    table.row(&[format!("quantize f32->q8 ({QN} elems)"), us(s.p50), us(s.p95)]);
    let s = bench(5, 500, || {
        let _ = std::hint::black_box(&q).dequantize();
    });
    table.row(&["dequantize q8->f32".into(), us(s.p50), us(s.p95)]);

    // --- codec: compressed vs f32 frames (reused encode buffer = the
    // steady-state TCP send path) ---
    let mut wbuf: Vec<u8> = Vec::new();
    codec::encode_into(&mut wbuf, 0, &msg_f32);
    let enc_f32 = bench(10, 1000, || {
        codec::encode_into(&mut wbuf, 0, &msg_f32);
    });
    table.row(&[
        format!("codec encode f32 ({} KiB frame)", frame_f32.len() / 1024),
        format!("{} ({:.2} GB/s)", us(enc_f32.p50), frame_f32.len() as f64 / enc_f32.p50 / 1e9),
        us(enc_f32.p95),
    ]);
    let mut qbuf: Vec<u8> = Vec::new();
    codec::encode_into(&mut qbuf, 0, &msg_q8);
    let enc_q8 = bench(10, 1000, || {
        codec::encode_into(&mut qbuf, 0, &msg_q8);
    });
    table.row(&[
        format!("codec encode q8 ({} KiB frame)", frame_q8.len() / 1024),
        format!("{} ({:.2} GB/s)", us(enc_q8.p50), frame_q8.len() as f64 / enc_q8.p50 / 1e9),
        us(enc_q8.p95),
    ]);
    let dec_f32 = bench(10, 1000, || {
        let _ = codec::decode(std::hint::black_box(&frame_f32)).unwrap();
    });
    table.row(&["codec decode f32".into(), us(dec_f32.p50), us(dec_f32.p95)]);
    let dec_q8 = bench(10, 1000, || {
        let _ = codec::decode(std::hint::black_box(&frame_q8)).unwrap();
    });
    table.row(&["codec decode q8".into(), us(dec_q8.p50), us(dec_q8.p95)]);

    // --- weight blocks: the ReplicaPush/Weights path (per-tensor q8,
    // per-channel q8, and the packed q4 replica arm on a 128x128 block) ---
    let q8pc = QTensor::quantize_weights(&xs, ChannelHint::Rows(128), Bits::B8);
    let q4pc = QTensor::quantize_weights(&xs, ChannelHint::Rows(128), Bits::B4);
    let wmsg_f32 = Message::Weights { blocks: vec![(3, vec![WireTensor::F32(act.clone())])] };
    let wmsg_q8 = Message::Weights { blocks: vec![(3, vec![WireTensor::Quant(q.clone())])] };
    let wmsg_q4 = Message::Weights { blocks: vec![(3, vec![WireTensor::Quant(q4pc.clone())])] };
    let wframe_f32 = codec::encode(0, &wmsg_f32);
    let wframe_q8 = codec::encode(0, &wmsg_q8);
    let wframe_q4 = codec::encode(0, &wmsg_q4);
    table.row(&[
        "weights frame f32 vs q8".into(),
        format!("{} B vs {} B", wframe_f32.len(), wframe_q8.len()),
        format!("{:.2}x", wframe_f32.len() as f64 / wframe_q8.len() as f64),
    ]);
    table.row(&[
        "replica frame f32 vs q4 (per-channel)".into(),
        format!("{} B vs {} B", wframe_f32.len(), wframe_q4.len()),
        format!("{:.2}x", wframe_f32.len() as f64 / wframe_q4.len() as f64),
    ]);
    let s = bench(5, 200, || {
        let _ = QTensor::quantize_weights(
            std::hint::black_box(&xs),
            ChannelHint::Rows(128),
            Bits::B4,
        );
    });
    table.row(&[format!("quantize f32->q4 per-channel ({QN} elems)"), us(s.p50), us(s.p95)]);
    let s = bench(5, 200, || {
        let _ = std::hint::black_box(&q4pc).dequantize();
    });
    table.row(&["dequantize q4->f32".into(), us(s.p50), us(s.p95)]);
    let s = bench(5, 200, || {
        let _ = std::hint::black_box(&q8pc).dequantize();
    });
    table.row(&["dequantize q8 per-channel->f32".into(), us(s.p50), us(s.p95)]);

    // --- payload handling: the old deep copy vs the TensorBuf share ---
    let raw: Vec<f32> = act.to_vec();
    let s = bench(10, 1000, || {
        let copied = raw.clone();
        std::hint::black_box(&copied);
    });
    table.row(&[format!("activation deep copy ({} KiB)", QN * 4 / 1024), us(s.p50), us(s.p95)]);
    let s = bench(10, 1000, || {
        let shared = act.clone();
        std::hint::black_box(&shared);
    });
    table.row(&["activation TensorBuf clone (shared)".into(), us(s.p50), us(s.p95)]);

    // --- gate metrics (byte ratios + same-process relative timings) ---
    metrics.push((
        "forward_f32_over_q8_bytes".to_string(),
        frame_f32.len() as f64 / frame_q8.len() as f64,
    ));
    metrics.push((
        "weights_f32_over_q8_bytes".to_string(),
        wframe_f32.len() as f64 / wframe_q8.len() as f64,
    ));
    metrics.push((
        "replica_f32_over_q4_bytes".to_string(),
        wframe_f32.len() as f64 / wframe_q4.len() as f64,
    ));
    metrics.push((
        "replica_q8_over_q4_bytes".to_string(),
        wframe_q8.len() as f64 / wframe_q4.len() as f64,
    ));
    metrics.push(("q8_encode_over_f32_encode".to_string(), enc_q8.p50 / enc_f32.p50));
    metrics.push(("q8_decode_over_f32_decode".to_string(), dec_q8.p50 / dec_f32.p50));
}

/// The shared coordinator phase machine (`coordinator::core`) driven flat
/// out through a synthetic 64-worker fault storm: one round is a fault
/// detection, 63 probe acks each followed by a driver poll, the probe
/// resolution, a redistribution with 63 fetch acks + polls, and the
/// commit — 254 `step` calls ending back in `Training`. Both drivers sit
/// on this dispatch for every control-plane message, so
/// `coord_step_transitions_per_sec` is gated (loosely — the pure match
/// runs in the millions/s; only an accidental clone of the ack sets per
/// step would move it by integer factors).
fn coordinator_section(table: &mut Table, metrics: &mut Vec<(String, f64)>) {
    use ftpipehd::coordinator::{PhaseConfig, PhaseInput, PhaseMachine, RedistReason};
    use std::collections::BTreeSet;
    use std::time::Duration;

    const WORKERS: usize = 64;
    let peers: Vec<usize> = (1..WORKERS).collect();
    let expect: BTreeSet<usize> = peers.iter().copied().collect();
    let t0 = Duration::from_millis(1_000);

    let mut m = PhaseMachine::new(PhaseConfig {
        probe_window: Duration::from_millis(100),
        redist_window: Duration::from_millis(500),
    });
    m.step(PhaseInput::TrainingStarted).expect("idle -> training");

    let mut storm_round = |m: &mut PhaseMachine| -> u64 {
        let mut steps = 0u64;
        let mut go = |m: &mut PhaseMachine, input: PhaseInput| {
            m.step(input).expect("storm inputs are all legal");
            steps += 1;
        };
        go(m, PhaseInput::FaultDetected { overdue: 7, now: t0 });
        for &d in &peers {
            go(m, PhaseInput::ProbeAck { id: d, fresh: false });
            // the drivers poll after every control message; the last ack
            // completes the set, so its poll resolves the probe
            go(
                m,
                PhaseInput::Poll {
                    now: t0 + Duration::from_millis(1),
                    overdue: Some(7),
                    inflight: 0,
                    peers: peers.len(),
                    local_fetch_done: true,
                },
            );
        }
        go(
            m,
            PhaseInput::RedistributionStarted {
                expect: expect.clone(),
                reason: RedistReason::Fault,
                now: t0 + Duration::from_millis(2),
            },
        );
        for &d in &peers {
            go(m, PhaseInput::FetchDone { id: d });
            go(
                m,
                PhaseInput::Poll {
                    now: t0 + Duration::from_millis(3),
                    overdue: None,
                    inflight: 0,
                    peers: peers.len(),
                    local_fetch_done: true,
                },
            );
        }
        // keep the transition log flat across iterations
        let _ = m.take_log();
        steps
    };

    let steps_per_round = storm_round(&mut m);
    let s = bench(10, 500, || {
        storm_round(&mut m);
    });
    let tps = steps_per_round as f64 / s.p50;
    table.row(&[
        format!("phase machine fault storm ({WORKERS} workers, {steps_per_round} steps)"),
        format!("{} ({:.2}M steps/s)", us(s.p50), tps / 1e6),
        us(s.p95),
    ]);
    metrics.push(("coord_step_transitions_per_sec".to_string(), tps));
}

/// The replica sync barrier (DESIGN.md §14) driven flat out: one round
/// is a `SyncDue` opening the barrier for 7 chains, a partial + driver
/// poll per chain (the last poll resolves), ending back in `Training` —
/// 16 `step` calls per round. The replica sim driver sits on this
/// dispatch once per `sync_every` committed batches per chain, so
/// `replica_sync_rounds_per_sec` is gated (loosely — the pure match
/// runs in the hundreds of thousands of rounds/s; only an accidental
/// clone of the expect/done sets per step would move it by integer
/// factors).
fn replica_sync_section(table: &mut Table, metrics: &mut Vec<(String, f64)>) {
    use ftpipehd::coordinator::{PhaseConfig, PhaseInput, PhaseMachine};
    use std::collections::BTreeSet;
    use std::time::Duration;

    const CHAINS: usize = 8; // chain 0 is local; 1..8 ship partials
    let expect: BTreeSet<usize> = (1..CHAINS).collect();
    let t0 = Duration::from_millis(1_000);

    let mut m = PhaseMachine::new(PhaseConfig {
        probe_window: Duration::from_millis(100),
        redist_window: Duration::from_millis(500),
    });
    m.step(PhaseInput::TrainingStarted).expect("idle -> training");

    let mut round_no = 0u64;
    let mut sync_round = |m: &mut PhaseMachine| {
        round_no += 1;
        m.step(PhaseInput::SyncDue { round: round_no, expect: expect.clone() })
            .expect("training -> syncing");
        for c in 1..CHAINS {
            m.step(PhaseInput::SyncPartial { chain: c }).expect("partial");
            // the driver polls after every partial; the last one resolves
            m.step(PhaseInput::Poll {
                now: t0 + Duration::from_millis(1),
                overdue: None,
                inflight: 0,
                peers: 0,
                local_fetch_done: true,
            })
            .expect("poll");
        }
        let _ = m.take_log();
    };

    sync_round(&mut m);
    let s = bench(10, 500, || {
        sync_round(&mut m);
    });
    let rps = 1.0 / s.p50;
    table.row(&[
        format!("phase machine sync barrier ({} chains)", CHAINS - 1),
        format!("{} ({:.0}k rounds/s)", us(s.p50), rps / 1e3),
        us(s.p95),
    ]);
    metrics.push(("replica_sync_rounds_per_sec".to_string(), rps));
}

/// The per-destination adaptive-compression controller driven flat out:
/// one round feeds all 64 destination ladders an LCG rate schedule that
/// crosses every threshold band, so escalations, hysteresis holds, and
/// relaxations all churn the override map. Both drivers call `observe`
/// once per `BwReport`, so `adaptive_observe_per_sec` is gated (loosely
/// — the map ops run in the millions/s; only an accidental rebuild of
/// the override table per observation would move it by integer factors).
fn adaptive_section(table: &mut Table, metrics: &mut Vec<(String, f64)>) {
    use ftpipehd::net::quant::{AdaptivePolicy, AdaptiveThresholds};

    const DESTS: usize = 64;
    let mut policy = AdaptivePolicy::new(AdaptiveThresholds::default());
    let mut state: u64 = 0x2545_F491_4F6C_DD1D;
    let mut round = |policy: &mut AdaptivePolicy| -> u64 {
        for d in 1..=DESTS {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // top bits of the LCG, offset into [1e4, ~1.7e7) B/s: spans
            // all four bands of the default thresholds
            let bps = 1e4 + (state >> 40) as f64;
            let _ = std::hint::black_box(policy.observe(d, bps));
        }
        DESTS as u64
    };
    let obs_per_round = round(&mut policy);
    let s = bench(10, 500, || {
        round(&mut policy);
    });
    let ops = obs_per_round as f64 / s.p50;
    table.row(&[
        format!("adaptive observe sweep ({DESTS} links)"),
        format!("{} ({:.2}M obs/s)", us(s.p50), ops / 1e6),
        us(s.p95),
    ]);
    metrics.push(("adaptive_observe_per_sec".to_string(), ops));
}

/// The scenario engine under storm load: a 48-device rolling-churn storm
/// measures event throughput (`sim_events_per_sec`), and the tentpole
/// 500-device storm records end-to-end wall time
/// (`storm_500dev_wall_s`). Both are gated as complexity tripwires with
/// deliberately loose baselines (see BENCH_BASELINE.json's note): an
/// accidental O(n) in the event queue or an allocation storm in the hot
/// path moves these by integer factors, far past any runner noise.
fn sim_section(table: &mut Table, metrics: &mut Vec<(String, f64)>) {
    use ftpipehd::sim::big_cluster_storm;
    use ftpipehd::sim::fixture::{materialize, FixtureSpec};
    use ftpipehd::sim::runner::run_scenario;
    use std::time::Instant;

    let storm = |n: usize, batches: u64| -> (f64, u64) {
        let dir = std::env::temp_dir()
            .join(format!("ftpipehd-bench-sim-{n}-{}", std::process::id()));
        let sc = big_cluster_storm(n, batches, 7);
        let spec = FixtureSpec { n_blocks: n + 12, dim: 8, classes: 4, batch: 4, seed: 11 };
        materialize(&dir, &spec).expect("sim fixture");
        let t0 = Instant::now();
        let out = run_scenario(&sc, &dir).expect("storm scenario");
        let secs = t0.elapsed().as_secs_f64();
        let _ = std::fs::remove_dir_all(&dir);
        (secs, out.events)
    };

    let (secs, events) = storm(48, 10);
    let eps = events as f64 / secs.max(1e-9);
    table.row(&[
        "sim storm 48 devices".into(),
        format!("{:.0} events/s", eps),
        format!("{events} events in {:.2} s", secs),
    ]);
    metrics.push(("sim_events_per_sec".to_string(), eps));

    let (secs, events) = storm(500, 10);
    table.row(&[
        "sim storm 500 devices (tentpole)".into(),
        format!("{:.2} s wall", secs),
        format!("{events} events"),
    ]);
    metrics.push(("storm_500dev_wall_s".to_string(), secs));
}

/// The event-driven TCP transport over loopback: small-message rate
/// (driver wakeups + write coalescing dominate) and bulk byte rate
/// (vectored writes + the frame assembler dominate). Loopback removes
/// the physical network, so these are transport-overhead tripwires:
/// `tcp_msgs_per_sec` and `tcp_bytes_per_sec` are gated an order of
/// magnitude below measured release-build values, and only a syscall
/// storm (losing coalescing, a wakeup per frame) or an accidental copy
/// per frame moves them by integer factors.
fn tcp_section(table: &mut Table, metrics: &mut Vec<(String, f64)>) {
    use ftpipehd::net::{loopback_cluster, Transport};
    use std::time::{Duration, Instant};

    let mut eps = loopback_cluster(2, 47310).expect("loopback TCP pair");
    let e1 = eps.pop().unwrap();
    let e0 = eps.pop().unwrap();
    std::thread::sleep(Duration::from_millis(100)); // listeners up

    // --- small-message rate: send-then-drain over one link ---
    const SMALL: u64 = 5000;
    let t0 = Instant::now();
    for b in 0..SMALL {
        e0.send(1, Message::Labels { batch: b, is_eval: false, data: vec![1] })
            .expect("loopback send");
    }
    for _ in 0..SMALL {
        e1.recv_timeout(Duration::from_secs(30)).expect("loopback small burst");
    }
    let secs = t0.elapsed().as_secs_f64();
    let msgs_per_sec = SMALL as f64 / secs;
    table.row(&[
        format!("tcp loopback small msgs ({SMALL} x Labels)"),
        format!("{:.0} msgs/s", msgs_per_sec),
        format!("{:.2} ms total", secs * 1e3),
    ]);
    metrics.push(("tcp_msgs_per_sec".to_string(), msgs_per_sec));

    // --- bulk byte rate: 48 x 256 KiB activation frames ---
    const BULK: usize = 48;
    const ELEMS: usize = 65_536; // 256 KiB of f32 per frame
    let payload: Vec<f32> = vec![0.25; ELEMS];
    let t0 = Instant::now();
    for b in 0..BULK {
        e0.send(
            1,
            Message::Forward {
                batch: b as u64,
                version0: 0,
                is_eval: false,
                data: Payload::F32(payload.clone().into()),
            },
        )
        .expect("loopback send");
    }
    for _ in 0..BULK {
        e1.recv_timeout(Duration::from_secs(60)).expect("loopback bulk burst");
    }
    let secs = t0.elapsed().as_secs_f64();
    let bytes_per_sec = (BULK * ELEMS * 4) as f64 / secs;
    table.row(&[
        format!("tcp loopback bulk ({BULK} x {} KiB)", ELEMS * 4 / 1024),
        format!("{:.2} MB/s", bytes_per_sec / 1e6),
        format!("{:.2} ms total", secs * 1e3),
    ]);
    metrics.push(("tcp_bytes_per_sec".to_string(), bytes_per_sec));

    e0.shutdown();
    e1.shutdown();
}

fn pjrt_section(model: &str, table: &mut Table) {
    let manifest = Manifest::load(model).expect("manifest");
    let engine = Engine::cpu().expect("engine");
    let blocks = load_all_blocks(&engine, &manifest).expect("blocks");

    // --- block execution: first IR block fwd + bwd ---
    let b = &blocks[1];
    let params = manifest.load_init_params(1).expect("params");
    let in_elems: usize = b.info.in_shape.iter().product();
    let x = match b.info.in_dtype {
        Dtype::F32 => HostTensor::F32(vec![0.1; in_elems].into()),
        Dtype::I32 => HostTensor::I32(vec![1; in_elems]),
    };
    let y = b.forward(&params, &x).expect("fwd");
    let gy = vec![1e-3f32; y.len()];
    let s = bench(5, 50, || {
        let _ = b.forward(&params, &x).unwrap();
    });
    table.row(&["block fwd (ir, via PJRT)".into(), ms(s.mean), ms(s.p95)]);
    let s = bench(5, 50, || {
        let _ = b.backward(&params, &x, &gy).unwrap();
    });
    table.row(&["block bwd (ir, via PJRT)".into(), ms(s.mean), ms(s.p95)]);

    // --- stem (the heaviest block) ---
    let b0 = &blocks[0];
    let p0 = manifest.load_init_params(0).expect("params");
    let in0: usize = b0.info.in_shape.iter().product();
    let x0 = HostTensor::F32(vec![0.1; in0].into());
    let s = bench(3, 30, || {
        let _ = b0.forward(&p0, &x0).unwrap();
    });
    table.row(&["block fwd (stem 3072->128)".into(), ms(s.mean), ms(s.p95)]);
}

fn main() {
    let mut table = Table::new(&["case", "mean/p50", "p95"]);
    let mut metrics: Vec<(String, f64)> = Vec::new();

    quant_codec_section(&mut table, &mut metrics);
    coordinator_section(&mut table, &mut metrics);
    replica_sync_section(&mut table, &mut metrics);
    adaptive_section(&mut table, &mut metrics);
    tcp_section(&mut table, &mut metrics);
    sim_section(&mut table, &mut metrics);

    let model = common::model_dir("artifacts/edgenet");
    if common::require_artifacts(&model) {
        pjrt_section(&model, &mut table);
    } else {
        println!("(model artifacts absent — PJRT rows skipped; codec/quant rows above)");
    }

    println!("# micro: data-plane hot path\n");
    table.print();
    emit_json_with_metrics("micro_runtime", Some(&table), &metrics);
}
