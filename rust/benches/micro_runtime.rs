//! Microbench: the data-plane hot path — block execution through PJRT
//! (with the literal conversions the pipeline pays per hop) and the
//! message codec. These bound the per-batch overhead the coordinator adds
//! on top of raw XLA compute; see EXPERIMENTS.md §Perf.

mod common;

use ftpipehd::manifest::{Dtype, Manifest};
use ftpipehd::net::codec;
use ftpipehd::net::message::{Message, Payload};
use ftpipehd::runtime::{load_all_blocks, Engine, HostTensor};
use ftpipehd::util::benchkit::{bench, emit_json, Table};

fn main() {
    let model = common::model_dir("artifacts/edgenet");
    if !common::require_artifacts(&model) {
        // still emit the JSON artifact (marked skipped) for the CI
        // bench-smoke job's BENCH_* trajectory
        emit_json("micro_runtime", None);
        return;
    }
    let manifest = Manifest::load(&model).expect("manifest");
    let engine = Engine::cpu().expect("engine");
    let blocks = load_all_blocks(&engine, &manifest).expect("blocks");
    let mut table = Table::new(&["case", "mean", "p95"]);

    // --- block execution: first IR block fwd + bwd ---
    let b = &blocks[1];
    let params = manifest.load_init_params(1).expect("params");
    let in_elems: usize = b.info.in_shape.iter().product();
    let x = match b.info.in_dtype {
        Dtype::F32 => HostTensor::F32(vec![0.1; in_elems].into()),
        Dtype::I32 => HostTensor::I32(vec![1; in_elems]),
    };
    let y = b.forward(&params, &x).expect("fwd");
    let gy = vec![1e-3f32; y.len()];
    let s = bench(5, 50, || {
        let _ = b.forward(&params, &x).unwrap();
    });
    table.row(&["block fwd (ir, via PJRT)".into(), format!("{:.2} ms", s.mean * 1e3), format!("{:.2} ms", s.p95 * 1e3)]);
    let s = bench(5, 50, || {
        let _ = b.backward(&params, &x, &gy).unwrap();
    });
    table.row(&["block bwd (ir, via PJRT)".into(), format!("{:.2} ms", s.mean * 1e3), format!("{:.2} ms", s.p95 * 1e3)]);

    // --- stem (the heaviest block) ---
    let b0 = &blocks[0];
    let p0 = manifest.load_init_params(0).expect("params");
    let in0: usize = b0.info.in_shape.iter().product();
    let x0 = HostTensor::F32(vec![0.1; in0].into());
    let s = bench(3, 30, || {
        let _ = b0.forward(&p0, &x0).unwrap();
    });
    table.row(&["block fwd (stem 3072->128)".into(), format!("{:.2} ms", s.mean * 1e3), format!("{:.2} ms", s.p95 * 1e3)]);

    // --- codec throughput on a Forward-sized message ---
    let act: usize = manifest.blocks[0].out_shape.iter().product();
    let act_buf = ftpipehd::net::TensorBuf::from(vec![0.5f32; act]);
    let msg = Message::Forward {
        batch: 1,
        version0: 1,
        is_eval: false,
        data: Payload::F32(act_buf.clone()),
    };
    let frame = codec::encode(0, &msg);
    let bytes = frame.len() as f64;
    let s = bench(10, 2000, || {
        let _ = codec::encode(0, &msg);
    });
    table.row(&[
        format!("codec encode ({} KiB act, fresh buf)", (bytes / 1024.0) as u64),
        format!("{:.1} us ({:.2} GB/s)", s.mean * 1e6, bytes / s.mean / 1e9),
        format!("{:.1} us", s.p95 * 1e6),
    ]);
    // the TCP send path: serialize into one long-lived frame buffer
    let mut wbuf: Vec<u8> = Vec::new();
    codec::encode_into(&mut wbuf, 0, &msg);
    let s = bench(10, 2000, || {
        codec::encode_into(&mut wbuf, 0, &msg);
    });
    table.row(&[
        "codec encode_into (reused buf)".into(),
        format!("{:.1} us ({:.2} GB/s)", s.mean * 1e6, bytes / s.mean / 1e9),
        format!("{:.1} us", s.p95 * 1e6),
    ]);
    let s = bench(10, 2000, || {
        let _ = codec::decode(&frame).unwrap();
    });
    table.row(&[
        "codec decode".into(),
        format!("{:.1} us ({:.2} GB/s)", s.mean * 1e6, bytes / s.mean / 1e9),
        format!("{:.1} us", s.p95 * 1e6),
    ]);

    // --- payload handling: the old deep copy vs the TensorBuf share ---
    // (this delta is what every queue/stash/replica hop on the sim
    // transport now saves; see rust/tests/zero_copy.rs for the proofs)
    let raw: Vec<f32> = act_buf.to_vec();
    let s = bench(10, 2000, || {
        let copied = raw.clone();
        std::hint::black_box(&copied);
    });
    table.row(&[
        format!("activation deep copy ({} KiB)", (act * 4) as u64 / 1024),
        format!("{:.2} us", s.mean * 1e6),
        format!("{:.2} us", s.p95 * 1e6),
    ]);
    let s = bench(10, 2000, || {
        let shared = act_buf.clone();
        std::hint::black_box(&shared);
    });
    table.row(&[
        "activation TensorBuf clone (shared)".into(),
        format!("{:.3} us", s.mean * 1e6),
        format!("{:.3} us", s.p95 * 1e6),
    ]);

    println!("# micro: data-plane hot path\n");
    table.print();
    emit_json("micro_runtime", Some(&table));
}
