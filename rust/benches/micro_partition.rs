//! Microbench: the eq-(5) dynamic-programming partitioner and the
//! Algorithm-1 redistribution planner — the two pure-logic hot paths of
//! the control plane (they run on every dynamic re-partition and every
//! fault recovery, so they must be negligible next to a batch).

mod common;

use ftpipehd::fault::plan_redistribution;
use ftpipehd::partition::{bruteforce_partition, optimal_partition, uniform_partition, CostModel};
use ftpipehd::util::benchkit::{bench, Table};
use ftpipehd::util::rng::Rng;

fn cost_model(n_blocks: usize, n_dev: usize, rng: &mut Rng) -> CostModel {
    CostModel {
        t0_ms: (0..n_blocks).map(|_| rng.uniform(1.0, 30.0)).collect(),
        out_bytes: (0..n_blocks).map(|_| rng.uniform(1e4, 1e6) as u64).collect(),
        capacities: (0..n_dev)
            .map(|i| if i == 0 { 1.0 } else { rng.uniform(0.5, 10.0) })
            .collect(),
        bandwidth_bps: (0..n_dev - 1).map(|_| rng.uniform(1e6, 1e8)).collect(),
    }
}

fn main() {
    let mut table = Table::new(&["case", "mean", "p95"]);
    let mut rng = Rng::new(7);

    for (blocks, devs) in [(12usize, 3usize), (24, 4), (48, 8), (96, 8)] {
        let cm = cost_model(blocks, devs, &mut rng);
        let s = bench(10, 200, || {
            let _ = optimal_partition(&cm);
        });
        table.row(&[
            format!("dp {blocks} blocks x {devs} devices"),
            format!("{:.1} us", s.mean * 1e6),
            format!("{:.1} us", s.p95 * 1e6),
        ]);
    }

    // brute force as the reference point (why the DP matters)
    let cm = cost_model(16, 4, &mut rng);
    let s = bench(3, 20, || {
        let _ = bruteforce_partition(&cm);
    });
    table.row(&[
        "bruteforce 16 blocks x 4 devices".into(),
        format!("{:.1} us", s.mean * 1e6),
        format!("{:.1} us", s.p95 * 1e6),
    ]);

    for (blocks, devs) in [(12usize, 4usize), (96, 8)] {
        let p_cur = uniform_partition(blocks, devs);
        let p_new = uniform_partition(blocks, devs - 1);
        let held: Vec<usize> = (p_cur[2].0..=p_cur[2].1).collect();
        let s = bench(10, 500, || {
            let _ = plan_redistribution(&p_new, &p_cur, &[1], &held, 1, Some(2));
        });
        table.row(&[
            format!("algorithm-1 plan {blocks} blocks x {devs} stages"),
            format!("{:.2} us", s.mean * 1e6),
            format!("{:.2} us", s.p95 * 1e6),
        ]);
    }

    println!("# micro: control-plane logic\n");
    table.print();
}
