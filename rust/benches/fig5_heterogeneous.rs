//! Paper Fig. 5 + §IV-D: convergence and wall-clock of FTPipeHD vs the
//! PipeDream-style static partition vs single-device training when the
//! best device is 10x faster than the worst.
//!
//! Paper result: FTPipeHD converges 6.8x faster than PipeDream (whose
//! static uniform partition leaves the slow device as the bottleneck) and
//! also beats both single machines. Expected shape: FTPipeHD's steady
//! ms/batch well below PipeDream's; speedup grows with the skew.

mod common;

use ftpipehd::config::Engine;
use ftpipehd::coordinator::run_sim;
use ftpipehd::util::benchkit::Table;

fn main() {
    let model = common::model_dir("artifacts/edgenet");
    if !common::require_artifacts(&model) {
        return;
    }
    let batches = common::scaled(60);

    println!("# Fig 5 / §IV-D: heterogeneous training, capacities [1, 1, skew]\n");
    let mut table = Table::new(&[
        "skew",
        "engine",
        "wall s",
        "steady ms/batch",
        "final loss",
        "val acc",
        "speedup vs pipedream",
    ]);

    for skew in [2.0, 10.0] {
        let mut steady_ms = std::collections::BTreeMap::new();
        for (name, engine) in [
            ("ftpipehd", Engine::FtPipeHd),
            ("pipedream", Engine::PipeDream),
            ("single", Engine::SingleDevice),
        ] {
            let mut cfg = common::base_cfg(&model, &[1.0, 1.0, skew], batches);
            cfg.engine = engine;
            cfg.repartition_first = Some(10);
            cfg.repartition_every = Some(50);
            if engine == Engine::SingleDevice {
                cfg.devices.truncate(1);
            }
            let record = run_sim(&cfg).expect("run");
            let steady = record
                .mean_batch_ms(batches as u64 / 2, batches as u64)
                .unwrap_or(f64::NAN);
            steady_ms.insert(name, steady);
            let speedup = if name == "ftpipehd" || name == "single" {
                steady_ms
                    .get("pipedream")
                    .map(|p| format!("{:.2}x", p / steady))
                    .unwrap_or_else(|| "-".into())
            } else {
                "1.00x".into()
            };
            table.row(&[
                format!("{skew}"),
                name.to_string(),
                format!("{:.1}", record.total_s),
                format!("{steady:.1}"),
                format!("{:.4}", record.final_loss().unwrap_or(f32::NAN)),
                format!(
                    "{:.3}",
                    record.epochs.last().map(|e| e.val_acc).unwrap_or(f32::NAN)
                ),
                speedup,
            ]);
        }
        // run pipedream FIRST would be needed for in-row speedups; recompute:
        let pd = steady_ms["pipedream"];
        let ft = steady_ms["ftpipehd"];
        println!(
            "skew {skew}: FTPipeHD {:.1} ms/batch vs PipeDream {:.1} ms/batch -> {:.2}x \
             (paper at 10x skew: 6.8x)",
            ft,
            pd,
            pd / ft
        );
    }
    println!();
    table.print();
}
