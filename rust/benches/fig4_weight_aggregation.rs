//! Paper Fig. 4: training/validation accuracy **with vs without weight
//! aggregation** in the asynchronous pipeline (§IV-C).
//!
//! Paper result: with aggregation the converged validation accuracy is
//! 82.38% vs 80.78% without (+1.6pp) on MobileNetV2/CIFAR10. Expected
//! shape here: the aggregated run's val accuracy >= the non-aggregated
//! run's at matched step counts (exact margins differ — synthetic data).

mod common;

use ftpipehd::coordinator::run_sim;
use ftpipehd::util::benchkit::print_series;

fn main() {
    let model = common::model_dir("artifacts/edgenet");
    if !common::require_artifacts(&model) {
        return;
    }
    let epochs = common::scaled(4);
    let batches = common::scaled(40);

    let mut series: Vec<Vec<f64>> = vec![];
    let mut finals = vec![];
    for agg in [Some(4usize), None] {
        let mut cfg = common::base_cfg(&model, &[1.0, 1.0, 1.0], batches);
        cfg.epochs = epochs;
        cfg.eval_batches = 8;
        cfg.agg_interval_k = agg;
        cfg.repartition_first = None; // isolate the aggregation effect
        cfg.repartition_every = None;
        cfg.seed = 0;
        let record = run_sim(&cfg).expect("run");
        let accs: Vec<f64> = record.epochs.iter().map(|e| e.val_acc as f64).collect();
        finals.push((agg.is_some(), *accs.last().unwrap_or(&f64::NAN)));
        series.push(accs);
        let train: Vec<f64> = record.epochs.iter().map(|e| e.train_acc as f64).collect();
        series.push(train);
    }

    let xs: Vec<f64> = (0..epochs).map(|e| e as f64).collect();
    print_series(
        "Fig 4: accuracy with/without weight aggregation",
        "epoch",
        &["val_acc(agg)", "train_acc(agg)", "val_acc(no-agg)", "train_acc(no-agg)"],
        &xs,
        &series,
    );
    println!(
        "\nfinal val acc: with aggregation {:.4}, without {:.4} (paper: 0.8238 vs 0.8078)",
        finals[0].1, finals[1].1
    );
}
