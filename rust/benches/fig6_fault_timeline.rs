//! Paper Fig. 6: per-batch training time around a worker failure —
//! FTPipeHD (weight redistribution + re-partition) vs ResPipe (the next
//! worker absorbs the failed stage).
//!
//! Paper result: both train a batch in ~2.1s before the fault; replication
//! causes a visible spike (batch 200; FTPipeHD's larger — it also runs
//! global replication); after recovery FTPipeHD returns to the pre-fault
//! per-batch time while ResPipe stays much slower (the takeover worker now
//! runs two stages' worth of blocks). The kill point here is scaled from
//! the paper's batch 205 to the bench's batch count.

mod common;

use ftpipehd::config::{Engine, FaultPlan};
use ftpipehd::coordinator::run_sim;
use ftpipehd::util::benchkit::print_series;

fn main() {
    let model = common::model_dir("artifacts/edgenet");
    if !common::require_artifacts(&model) {
        return;
    }
    let batches = common::scaled(60);
    let kill_at = (batches * 2 / 3) as u64; // paper: 205 of its window
    let chain = (batches / 6).max(2) as u64; // paper: every 50
    let global = chain * 2; // paper: every 100

    println!(
        "# Fig 6: per-batch time; kill worker 2 at batch {kill_at}; \
         chain every {chain}, global every {global}\n"
    );

    let mut all: Vec<Vec<f64>> = vec![];
    for engine in [Engine::FtPipeHd, Engine::ResPipe] {
        let mut cfg = common::base_cfg(&model, &[1.0, 1.0, 1.0, 1.0], batches);
        cfg.engine = engine;
        cfg.chain_every = Some(chain);
        cfg.global_every = Some(global);
        cfg.fault_timeout_ms = 3000;
        cfg.repartition_first = None;
        cfg.repartition_every = None;
        cfg.fault = Some(FaultPlan { kill_device: 2, at_batch: kill_at, restarts: false });
        let record = run_sim(&cfg).expect("run");

        let mut ys = vec![f64::NAN; batches];
        for b in &record.batches {
            ys[b.batch as usize] = b.wall_ms;
        }
        let before =
            record.mean_batch_ms(kill_at.saturating_sub(10), kill_at - 1).unwrap_or(f64::NAN);
        let after = record.mean_batch_ms(kill_at + 3, batches as u64).unwrap_or(f64::NAN);
        println!(
            "{:?}: before fault {before:.1} ms/batch, after recovery {after:.1} ms/batch ({}), \
             redistribution {:?}s",
            engine,
            if after < 1.5 * before { "returned to pre-fault speed" } else { "STILL DEGRADED" },
            record.recovery_overhead_s,
        );
        all.push(ys);
    }

    let xs: Vec<f64> = (0..batches).map(|b| b as f64).collect();
    print_series(
        "Fig 6: per-batch training time (ms)",
        "batch",
        &["ftpipehd_ms", "respipe_ms"],
        &xs,
        &all,
    );
}
