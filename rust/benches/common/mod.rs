//! Shared helpers for the per-figure benches.
//!
//! Each bench binary compiles this module independently and uses a
//! different subset of the helpers, so per-binary dead-code analysis
//! would flag whichever helpers that binary skips.
#![allow(dead_code)]

use ftpipehd::config::{DeviceConfig, RunConfig};

/// Scale factor for bench sizes (FTPIPEHD_BENCH_SCALE=2 doubles batches).
pub fn scale() -> f64 {
    std::env::var("FTPIPEHD_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

pub fn scaled(n: usize) -> usize {
    ((n as f64 * scale()).round() as usize).max(1)
}

pub fn model_dir(default: &str) -> String {
    std::env::var("FTPIPEHD_BENCH_MODEL").unwrap_or_else(|_| default.to_string())
}

pub fn base_cfg(model: &str, devices: &[f64], batches: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model_dir = model.to_string();
    cfg.devices = devices.iter().map(|&c| DeviceConfig::with_capacity(c)).collect();
    cfg.bandwidth_bps = vec![12.5e6];
    cfg.epochs = 1;
    cfg.batches_per_epoch = batches;
    cfg.eval_batches = 5;
    cfg
}

pub fn require_artifacts(dir: &str) -> bool {
    let ok = std::path::Path::new(dir).join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: {dir}/manifest.json missing — run `make artifacts`");
    }
    ok
}
