//! Paper Fig. 8 + §IV-F: continuous learning on Raspberry-Pi-class
//! devices. Pre-train on the old data domain, continue on mixed old+new
//! data across 3 devices; also reproduce the single-Pi OOM.
//!
//! Paper result: a single Pi dies at batch 499 (OOM); on 3 Pis the
//! accuracy drops to 43.81% when the new data arrives and then climbs
//! back to roughly the pre-trained level over the following epochs.

mod common;

use ftpipehd::config::{DeviceConfig, Engine, RunConfig};
use ftpipehd::coordinator::{run_sim, run_sim_full, RunOpts};
use ftpipehd::data::{MixedVision, SynthVision};
use ftpipehd::manifest::Manifest;
use ftpipehd::util::benchkit::print_series;

fn main() {
    let model = common::model_dir("artifacts/edgenet-pi");
    if !common::require_artifacts(&model) {
        return;
    }
    let manifest = Manifest::load(&model).expect("manifest");
    let dim: usize = manifest.input_shape.iter().skip(1).product();
    let classes = manifest.n_classes.unwrap_or(10);

    // --- single-Pi OOM (paper: process killed at batch 499) ---
    let needed = manifest.param_bytes_range(0, manifest.n_blocks() - 1) * 3;
    let mut cfg = RunConfig::default();
    cfg.model_dir = model.clone();
    cfg.engine = Engine::SingleDevice;
    cfg.devices = vec![DeviceConfig::default()];
    cfg.devices[0].mem_cap_bytes = Some(needed / 2);
    cfg.epochs = 1;
    cfg.batches_per_epoch = 5;
    cfg.eval_batches = 0;
    let r = run_sim(&cfg).expect("run");
    println!(
        "# single memory-capped device: {}",
        r.events.first().map(|e| e.kind.as_str()).unwrap_or("?")
    );
    println!("#   -> cannot train on one device (paper: OOM at batch 499)\n");

    // --- pretrain on old domain, then continue on mixed ---
    let pre_batches = common::scaled(60);
    let epochs = common::scaled(5);
    let per_epoch = common::scaled(30);

    let old = SynthVision::new(dim, classes, 0.6, 7, 0);
    let new = SynthVision::new(dim, classes, 0.6, 7, 1);

    let mut cfg = common::base_cfg(&model, &[1.0, 1.0, 1.0], pre_batches);
    cfg.eval_batches = 8;
    let pre = run_sim_full(
        &cfg,
        RunOpts {
            data: Some(Box::new(old.clone())),
            collect_final_weights: true,
            ..Default::default()
        },
    )
    .expect("pretrain");
    let pre_acc = pre.record.epochs.last().map(|e| e.val_acc).unwrap_or(f32::NAN);
    println!("# pre-trained val acc (old domain): {pre_acc:.3}");

    let mixed = MixedVision { old, new, new_frac: 0.5, seed: 99 };
    let mut cfg2 = common::base_cfg(&model, &[1.0, 1.0, 1.0], per_epoch);
    cfg2.epochs = epochs;
    cfg2.eval_batches = 8;
    let cont = run_sim_full(
        &cfg2,
        RunOpts {
            data: Some(Box::new(mixed)),
            initial_weights: Some(pre.final_weights),
            ..Default::default()
        },
    )
    .expect("continue");

    let early: f32 =
        cont.record.batches.iter().take(5).map(|b| b.train_acc).sum::<f32>() / 5.0;
    println!("# accuracy right after new data arrives: {early:.3} (paper: 43.81%)");

    let xs: Vec<f64> = (0..cont.record.epochs.len()).map(|e| e as f64).collect();
    let val: Vec<f64> = cont.record.epochs.iter().map(|e| e.val_acc as f64).collect();
    let train: Vec<f64> = cont.record.epochs.iter().map(|e| e.train_acc as f64).collect();
    print_series(
        "Fig 8: continuous-learning accuracy per epoch (validation on the NEW domain)",
        "epoch",
        &["val_acc_new_domain", "train_acc_mixed"],
        &xs,
        &[val.clone(), train],
    );
    println!(
        "\nfinal val acc on new domain {:.3} vs pre-trained level {:.3} \
         (paper: climbs back to pre-trained level)",
        val.last().unwrap_or(&f64::NAN),
        pre_acc
    );
}
