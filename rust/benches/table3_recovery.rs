//! Paper Table III: recovery overhead and one-epoch training time after
//! recovery — FTPipeHD vs ResPipe.
//!
//! Paper result: ResPipe recovers almost instantly (0.13s — no weights
//! move) but afterwards one epoch takes 59.18 min vs FTPipeHD's 8.57 min
//! (6.9x), because FTPipeHD pays 2.24s to redistribute weights and
//! re-balance. Expected shape: ResPipe's recovery overhead < FTPipeHD's;
//! FTPipeHD's post-recovery epoch time substantially lower.

mod common;

use ftpipehd::config::{Engine, FaultPlan};
use ftpipehd::coordinator::run_sim;
use ftpipehd::util::benchkit::Table;

fn main() {
    let model = common::model_dir("artifacts/edgenet");
    if !common::require_artifacts(&model) {
        return;
    }
    // heterogeneous pipeline so re-balancing matters after the failure
    let batches = common::scaled(60);
    let kill_at = (batches / 2) as u64;
    let chain = (batches / 6).max(2) as u64;

    println!("# Table III: fault recovery performance (kill worker 2 at batch {kill_at})\n");
    let mut table = Table::new(&[
        "",
        "FTPipeHD",
        "ResPipe",
    ]);

    let mut overheads = vec![];
    let mut epoch_times = vec![];
    for engine in [Engine::FtPipeHd, Engine::ResPipe] {
        let mut cfg = common::base_cfg(&model, &[1.0, 1.0, 1.0, 2.0], batches);
        cfg.engine = engine;
        cfg.chain_every = Some(chain);
        cfg.global_every = Some(chain * 2);
        cfg.fault_timeout_ms = 3000;
        cfg.fault = Some(FaultPlan { kill_device: 2, at_batch: kill_at, restarts: false });
        let record = run_sim(&cfg).expect("run");
        overheads.push(record.recovery_overhead_s.unwrap_or(f64::NAN));
        // "one-epoch training time after recovery": post-recovery ms/batch
        // extrapolated to a full epoch of `batches`
        let after_ms = record
            .mean_batch_ms(kill_at + 3, batches as u64)
            .unwrap_or(f64::NAN);
        epoch_times.push(after_ms * batches as f64 / 1e3);
    }

    table.row(&[
        "recover overhead (s)".into(),
        format!("{:.3}", overheads[0]),
        format!("{:.3}", overheads[1]),
    ]);
    table.row(&[
        "one-epoch time after recovery (s)".into(),
        format!("{:.1}", epoch_times[0]),
        format!("{:.1}", epoch_times[1]),
    ]);
    table.print();
    println!(
        "\nepoch-time ratio ResPipe/FTPipeHD: {:.2}x (paper: 6.9x on its 3-device testbed)",
        epoch_times[1] / epoch_times[0]
    );
    println!(
        "overhead ratio FTPipeHD/ResPipe: {:.2}x (paper: 2.24s vs 0.13s = 17x)",
        overheads[0] / overheads[1]
    );
}
