//! Ablations over FTPipeHD's design choices (DESIGN.md §5 "ablation
//! benches"): pipeline depth (in-flight limit), replication periods
//! (fault-tolerance cost in bytes + per-batch spikes), and capacity-drift
//! adaptation (time-varying devices, the paper's motivation for *dynamic*
//! re-partition).

mod common;

use ftpipehd::config::Engine;
use ftpipehd::coordinator::run_sim;
use ftpipehd::util::benchkit::Table;

fn main() {
    let model = common::model_dir("artifacts/edgenet");
    if !common::require_artifacts(&model) {
        return;
    }
    let batches = common::scaled(40);

    // ---- ablation 1: in-flight limit (async pipelining vs sync) ----
    println!("# Ablation 1: pipeline depth (in-flight limit); 3 equal devices\n");
    let mut t = Table::new(&["in-flight", "wall s", "steady ms/batch"]);
    for limit in [1usize, 2, 3, 6] {
        let mut cfg = common::base_cfg(&model, &[1.0, 1.0, 1.0], batches);
        cfg.inflight_limit = Some(limit);
        cfg.repartition_first = None;
        cfg.repartition_every = None;
        let r = run_sim(&cfg).expect("run");
        t.row(&[
            format!("{limit}{}", if limit == 1 { " (sync/model-parallel)" } else { "" }),
            format!("{:.1}", r.total_s),
            format!(
                "{:.1}",
                r.mean_batch_ms(batches as u64 / 2, batches as u64).unwrap_or(f64::NAN)
            ),
        ]);
    }
    t.print();

    // ---- ablation 2: replication period vs network cost ----
    println!("\n# Ablation 2: replication period -> network bytes (fault-tolerance cost)\n");
    let mut t = Table::new(&["chain/global period", "net MB", "overhead vs none"]);
    let mut base_mb = 0.0;
    for (chain, global) in [(None, None), (Some(20u64), Some(40u64)), (Some(5), Some(10))] {
        let mut cfg = common::base_cfg(&model, &[1.0, 1.0, 1.0], batches);
        cfg.chain_every = chain;
        cfg.global_every = global;
        cfg.repartition_first = None;
        cfg.repartition_every = None;
        let r = run_sim(&cfg).expect("run");
        let mb = r.net_bytes as f64 / 1e6;
        if chain.is_none() {
            base_mb = mb;
        }
        t.row(&[
            format!("{chain:?}/{global:?}"),
            format!("{mb:.2}"),
            format!("{:+.1}%", (mb - base_mb) / base_mb * 100.0),
        ]);
    }
    t.print();

    // ---- ablation 3: time-varying capacity (drift) ----
    println!(
        "\n# Ablation 3: capacity drift — dynamic re-partition vs static \
         under time-varying load\n"
    );
    let mut t = Table::new(&["engine", "drift", "steady ms/batch", "re-partitions"]);
    for (engine, name) in [(Engine::FtPipeHd, "ftpipehd"), (Engine::PipeDream, "pipedream")] {
        let mut cfg = common::base_cfg(&model, &[1.0, 1.0, 4.0], common::scaled(80));
        cfg.engine = engine;
        cfg.devices[2].drift_amp = 0.6;
        cfg.devices[2].drift_period_s = 20.0;
        cfg.repartition_first = Some(10);
        cfg.repartition_every = Some(25);
        let r = run_sim(&cfg).expect("run");
        t.row(&[
            name.to_string(),
            "±60% / 20s".into(),
            format!("{:.1}", r.mean_batch_ms(20, common::scaled(80) as u64).unwrap_or(f64::NAN)),
            format!("{}", r.partitions.len()),
        ]);
    }
    t.print();
}
