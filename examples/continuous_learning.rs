//! Continuous learning on Raspberry-Pi-class devices (paper §IV-F, Fig 8):
//! pre-train on the "old" data domain, then continue training on mixed
//! old+new data across 3 memory-constrained devices. Also demonstrates the
//! single-Pi OOM the paper hit (training dies on one device but fits on 3).
//!
//! ```sh
//! cargo run --release --example continuous_learning -- --pretrain 80 --continue-batches 80
//! ```

use anyhow::Result;
use ftpipehd::cli::Args;
use ftpipehd::config::{DeviceConfig, Engine, RunConfig};
use ftpipehd::coordinator::{run_sim, run_sim_full, RunOpts};
use ftpipehd::data::{MixedVision, SynthVision};
use ftpipehd::manifest::Manifest;

fn pi_devices(n: usize, mem_cap: Option<u64>) -> Vec<DeviceConfig> {
    (0..n)
        .map(|_| {
            let mut d = DeviceConfig::with_capacity(1.0);
            d.mem_cap_bytes = mem_cap;
            d
        })
        .collect()
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let model = args.get("model").unwrap_or("artifacts/edgenet-pi").to_string();
    let pretrain_batches = args.get_usize("pretrain", 80)?;
    let cont_batches = args.get_usize("continue-batches", 80)?;

    let manifest = Manifest::load(&model)?;
    let dim: usize = manifest.input_shape.iter().skip(1).product();
    let classes = manifest.n_classes.unwrap_or(10);

    // --- the paper's single-Pi OOM: the whole model does not fit ---
    let model_bytes = manifest.param_bytes_range(0, manifest.n_blocks() - 1) * 3;
    let pi_cap = model_bytes / 2; // a Pi with half the needed memory
    {
        let mut cfg = RunConfig::default();
        cfg.model_dir = model.clone();
        cfg.engine = Engine::SingleDevice;
        cfg.devices = pi_devices(1, Some(pi_cap));
        cfg.epochs = 1;
        cfg.batches_per_epoch = 10;
        cfg.eval_batches = 0;
        let record = run_sim(&cfg)?;
        println!("--- single memory-capped device ---");
        for e in &record.events {
            println!("  {}", e.kind);
        }
        assert!(record.batches.is_empty(), "expected the OOM path");
        println!("  -> training is impossible on one device (paper: killed at batch 499)\n");
    }

    // --- phase 1: pre-train on the old domain (90% of data, paper) ---
    let old = SynthVision::new(dim, classes, 0.6, 7, /*domain=*/ 0);
    let new = SynthVision::new(dim, classes, 0.6, 7, /*domain=*/ 1);

    let mut cfg = RunConfig::default();
    cfg.model_dir = model.clone();
    cfg.devices = pi_devices(3, None);
    cfg.epochs = 1;
    cfg.batches_per_epoch = pretrain_batches;
    cfg.eval_batches = 6;
    let pre = run_sim_full(
        &cfg,
        RunOpts {
            data: Some(Box::new(old.clone())),
            collect_final_weights: true,
            ..Default::default()
        },
    )?;
    println!(
        "pre-training done: val_acc(old domain) = {:.3}",
        pre.record.epochs.last().map(|e| e.val_acc).unwrap_or(f32::NAN)
    );

    // accuracy on the NEW domain with the pre-trained model (before adapting)
    // is measured by the first batches of phase 2 below.

    // --- phase 2: continue on mixed data (10% new mixed with old, §IV-F) ---
    let mixed = MixedVision { old, new, new_frac: 0.5, seed: 99 };
    let mut cfg2 = RunConfig::default();
    cfg2.model_dir = model;
    cfg2.devices = pi_devices(3, None);
    cfg2.epochs = 4;
    cfg2.batches_per_epoch = cont_batches / 4;
    cfg2.eval_batches = 6;
    let cont = run_sim_full(
        &cfg2,
        RunOpts {
            data: Some(Box::new(mixed)),
            initial_weights: Some(pre.final_weights),
            ..Default::default()
        },
    )?;

    println!("\ncontinuous learning (validation = NEW domain):");
    let early: f32 = cont.record.batches.iter().take(5).map(|b| b.train_acc).sum::<f32>() / 5.0;
    println!("  initial mixed-data accuracy: {early:.3} (drops on the new domain, then recovers)");
    for e in &cont.record.epochs {
        println!(
            "  epoch {}: train_acc={:.3} val_acc(new)={:.3}",
            e.epoch, e.train_acc, e.val_acc
        );
    }
    println!(
        "\n(paper Fig 8: accuracy dips with new data, then climbs back to the pre-trained level)"
    );
    Ok(())
}
