//! Quickstart: train a small model across 3 simulated edge devices with
//! the full FTPipeHD stack (async 1F1B pipeline + dynamic partitioning +
//! replication) and print the learning curve.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use ftpipehd::config::{DeviceConfig, RunConfig};
use ftpipehd::coordinator::run_sim;

fn main() -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.model_dir = "artifacts/edgenet-tiny".into();
    // three devices: the central node plus two workers, one 3x slower
    cfg.devices = vec![
        DeviceConfig::with_capacity(1.0),
        DeviceConfig::with_capacity(1.0),
        DeviceConfig::with_capacity(3.0),
    ];
    cfg.bandwidth_bps = vec![12.5e6]; // ~100 Mbit WiFi
    cfg.epochs = 2;
    cfg.batches_per_epoch = 50;
    cfg.eval_batches = 8;

    let record = run_sim(&cfg)?;

    println!("\n=== quickstart: FTPipeHD on 3 simulated devices ===");
    println!("{:>6} {:>10} {:>10}", "batch", "loss", "train_acc");
    for b in record.batches.iter().step_by(10) {
        println!("{:>6} {:>10.4} {:>10.3}", b.batch, b.loss, b.train_acc);
    }
    for e in &record.epochs {
        println!(
            "epoch {}: train_acc={:.3} val_loss={:.4} val_acc={:.3}",
            e.epoch, e.train_acc, e.val_loss, e.val_acc
        );
    }
    for (batch, p) in &record.partitions {
        println!("re-partitioned at batch {batch}: {p:?}");
    }
    println!(
        "total {:.1}s, {:.2} MB over the network",
        record.total_s,
        record.net_bytes as f64 / 1e6
    );
    Ok(())
}
