//! Fault tolerance (paper §IV-E / Fig 6): kill a worker mid-training and
//! watch detection, weight redistribution from replicas, and the per-batch
//! time before/after recovery — FTPipeHD vs ResPipe-style takeover.
//!
//! ```sh
//! cargo run --release --example fault_recovery -- --kill-at 30 --batches 60
//! ```

use anyhow::Result;
use ftpipehd::cli::Args;
use ftpipehd::config::{DeviceConfig, Engine, FaultPlan, RunConfig};
use ftpipehd::coordinator::run_sim;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let batches = args.get_usize("batches", 60)?;
    let kill_at = args.get_u64("kill-at", 30)?;
    let model = args.get("model").unwrap_or("artifacts/edgenet-tiny").to_string();

    for (name, engine) in [("FTPipeHD", Engine::FtPipeHd), ("ResPipe", Engine::ResPipe)] {
        let mut cfg = RunConfig::default();
        cfg.model_dir = model.clone();
        cfg.devices = vec![DeviceConfig::default(); 4];
        cfg.epochs = 1;
        cfg.batches_per_epoch = batches;
        cfg.eval_batches = 4;
        cfg.chain_every = Some(10);
        cfg.global_every = Some(20);
        cfg.fault_timeout_ms = 3000;
        cfg.fault = Some(FaultPlan { kill_device: 2, at_batch: kill_at, restarts: false });
        cfg.engine = engine;

        let record = run_sim(&cfg)?;
        println!("\n=== {name} ===");
        let before = record.mean_batch_ms(kill_at.saturating_sub(10), kill_at - 1);
        let after = record.mean_batch_ms(kill_at + 5, batches as u64);
        println!(
            "per-batch: before fault {:.1} ms, after recovery {:.1} ms",
            before.unwrap_or(f64::NAN),
            after.unwrap_or(f64::NAN)
        );
        if let Some(r) = record.recovery_overhead_s {
            println!("recovery overhead (redistribution): {r:.3} s");
        }
        for (b, p) in &record.partitions {
            println!("partition after recovery (batch {b}): {p:?}");
        }
        for e in record
            .events
            .iter()
            .filter(|e| e.kind.contains("fault") || e.kind.contains("recovery") || e.kind.contains("kill"))
        {
            println!("  [{:>6.2}s] {}", e.at_s, e.kind);
        }
        println!(
            "completed {}/{} batches; final val_acc {:.3}",
            record.batches.len(),
            batches,
            record.epochs.last().map(|e| e.val_acc).unwrap_or(f32::NAN)
        );
    }
    println!("\n(paper Table III: FTPipeHD pays more at recovery but trains 6.9x faster afterwards)");
    Ok(())
}
