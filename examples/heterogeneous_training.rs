//! Heterogeneous devices (paper §IV-D): FTPipeHD's dynamic capacity-aware
//! partitioning vs the PipeDream-style static uniform partition vs
//! single-device training, when the slowest device is K× slower.
//!
//! ```sh
//! cargo run --release --example heterogeneous_training -- --skew 10 --batches 60
//! ```

use anyhow::Result;
use ftpipehd::cli::Args;
use ftpipehd::config::{DeviceConfig, Engine, RunConfig};
use ftpipehd::coordinator::run_sim;
use ftpipehd::util::benchkit::Table;

fn cfg_base(model: &str, batches: usize, skew: f64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model_dir = model.to_string();
    cfg.devices = vec![
        DeviceConfig::with_capacity(1.0),
        DeviceConfig::with_capacity(1.0),
        DeviceConfig::with_capacity(skew),
    ];
    cfg.bandwidth_bps = vec![12.5e6];
    cfg.epochs = 1;
    cfg.batches_per_epoch = batches;
    cfg.eval_batches = 5;
    cfg.repartition_first = Some(10);
    cfg.repartition_every = Some(50);
    cfg
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let skew = args.get_f64("skew", 10.0)?;
    let batches = args.get_usize("batches", 60)?;
    let model = args.get("model").unwrap_or("artifacts/edgenet").to_string();

    println!("devices: [central 1.0, worker 1.0, worker {skew}] — {batches} batches of {model}");

    let mut table = Table::new(&[
        "engine",
        "wall s",
        "ms/batch (steady)",
        "final loss",
        "val acc",
    ]);

    for (name, engine) in [
        ("FTPipeHD", Engine::FtPipeHd),
        ("PipeDream (static)", Engine::PipeDream),
        ("single device", Engine::SingleDevice),
    ] {
        let mut cfg = cfg_base(&model, batches, skew);
        cfg.engine = engine;
        if engine == Engine::SingleDevice {
            cfg.devices.truncate(1);
        }
        let record = run_sim(&cfg)?;
        let steady = record
            .mean_batch_ms(batches as u64 / 2, batches as u64)
            .unwrap_or(f64::NAN);
        table.row(&[
            name.to_string(),
            format!("{:.1}", record.total_s),
            format!("{steady:.1}"),
            format!("{:.4}", record.final_loss().unwrap_or(f32::NAN)),
            format!("{:.3}", record.epochs.last().map(|e| e.val_acc).unwrap_or(f32::NAN)),
        ]);
    }
    table.print();
    println!("\n(the paper reports 6.8x FTPipeHD-vs-PipeDream at 10x capacity skew, §IV-D)");
    Ok(())
}
