//! End-to-end validation driver (DESIGN.md §5): train a decoder-only
//! transformer with the full three-layer stack — Pallas kernels inside the
//! JAX-lowered block artifacts, executed by the Rust 1F1B pipeline across
//! simulated heterogeneous devices — on a synthetic Zipf-Markov corpus,
//! and log the loss curve. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! # small config (CI-sized):
//! cargo run --release --example train_transformer -- --batches 200
//! # bigger model (compile pipeformer-e2e artifacts first):
//! cd python && python -m compile.aot --models pipeformer-e2e --out ../artifacts && cd ..
//! cargo run --release --example train_transformer -- --model artifacts/pipeformer-e2e --batches 300
//! ```

use anyhow::Result;
use ftpipehd::cli::Args;
use ftpipehd::config::{DeviceConfig, RunConfig};
use ftpipehd::coordinator::run_sim;
use ftpipehd::manifest::Manifest;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let model = args.get("model").unwrap_or("artifacts/pipeformer-small").to_string();
    let batches = args.get_usize("batches", 200)?;
    let devices = args.get_usize("devices", 3)?;
    let epochs = args.get_usize("epochs", 1)?;

    let manifest = Manifest::load(&model)?;
    println!(
        "pipeformer e2e: {} ({} params, {} blocks, batch {} x seq {})",
        manifest.model,
        manifest.param_count,
        manifest.n_blocks(),
        manifest.batch_size,
        manifest.seq.unwrap_or(0),
    );

    let mut cfg = RunConfig::default();
    cfg.model_dir = model;
    cfg.devices = vec![DeviceConfig::with_capacity(1.0); devices];
    cfg.bandwidth_bps = vec![50e6]; // fast LAN
    cfg.lr = args.get_f64("lr", 0.05)? as f32;
    cfg.epochs = epochs;
    cfg.batches_per_epoch = batches / epochs.max(1);
    cfg.eval_batches = 8;
    cfg.repartition_first = Some(10);
    cfg.repartition_every = Some(100);

    let record = run_sim(&cfg)?;

    println!("\nstep\tloss\ttoken_acc");
    for b in record.batches.iter().step_by((batches / 25).max(1)) {
        println!("{}\t{:.4}\t{:.3}", b.batch, b.loss, b.train_acc);
    }
    if let Some(last) = record.batches.last() {
        println!("{}\t{:.4}\t{:.3}", last.batch, last.loss, last.train_acc);
    }
    for e in &record.epochs {
        println!(
            "epoch {}: val_loss={:.4} val_token_acc={:.3}",
            e.epoch, e.val_loss, e.val_acc
        );
    }
    let first = record.batches.iter().take(10).map(|b| b.loss).sum::<f32>() / 10.0;
    let last = record.batches.iter().rev().take(10).map(|b| b.loss).sum::<f32>() / 10.0;
    println!(
        "\nloss {first:.3} -> {last:.3} over {} steps ({:.1}s wall, {:.1} MB network)",
        record.batches.len(),
        record.total_s,
        record.net_bytes as f64 / 1e6
    );
    if last >= first {
        eprintln!("WARNING: loss did not decrease — inspect hyper-parameters");
        std::process::exit(1);
    }
    Ok(())
}
